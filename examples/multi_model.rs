//! Multiple models in one engine (paper §2.1: "loading multiple models in
//! the same engine for applications like retrieval-augmented generation").
//!
//! A RAG-flavored pipeline over two models sharing one worker:
//!   1. the small model ("retriever-reranker" stand-in) scores candidate
//!      snippets by asking it to pick one under a grammar constraint;
//!   2. the larger model answers with the selected snippet in context.
//!
//! ```bash
//! cargo run --release --example multi_model
//! ```

use webllm::api::{ChatCompletionRequest, ResponseFormat};
use webllm::coordinator::{EngineConfig, ServiceWorkerMLCEngine};

const SNIPPETS: [&str; 3] = [
    "WebGPU exposes the GPU to JavaScript.",
    "Paged KV caches use fixed-size blocks.",
    "BPE merges frequent byte pairs.",
];

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("loading tiny-2m + phi-web-38m in one engine...");
    let mut engine =
        ServiceWorkerMLCEngine::create(EngineConfig::native(&["tiny-2m", "phi-web-38m"]))?;
    println!("models ready: {:?}", engine.models());

    let question = "How do browser apps reach the GPU?";

    // Stage 1 — constrained selection with the small model.
    let grammar = r#"root ::= "0" | "1" | "2""#;
    let mut select = ChatCompletionRequest::new("tiny-2m")
        .system("Pick the most relevant snippet index.")
        .user(format!(
            "Q: {question}\n0: {}\n1: {}\n2: {}",
            SNIPPETS[0], SNIPPETS[1], SNIPPETS[2]
        ));
    select.max_tokens = 2;
    select.sampling.seed = Some(3);
    select.response_format = ResponseFormat::Grammar(grammar.to_string());
    let choice = engine.chat_completion(select)?;
    let idx: usize = choice.text().trim().parse().unwrap_or(0);
    println!("retriever picked snippet {idx}: {:?}", SNIPPETS[idx]);

    // Stage 2 — grounded answer with the bigger model.
    let mut answer = ChatCompletionRequest::new("phi-web-38m")
        .system("Use the provided context.")
        .user(format!("Context: {}\nQuestion: {question}", SNIPPETS[idx]));
    answer.max_tokens = 24;
    answer.sampling.seed = Some(9);
    let resp = engine.chat_completion(answer)?;
    println!("answer ({}): {}", resp.model, resp.text());
    println!(
        "  [{} tok at {:.1} tok/s]",
        resp.usage.completion_tokens, resp.usage.decode_tokens_per_s
    );

    let stats = engine.stats()?;
    println!("\nper-model engine state:");
    println!("{}", webllm::json::to_string_pretty(&stats));
    Ok(())
}
