//! End-to-end serving driver (the EXPERIMENTS.md §E2E run): boots the
//! full stack — HTTP endpoint -> frontend engine -> worker thread ->
//! continuous-batching scheduler -> PJRT executables — fires a batch of
//! concurrent OpenAI-style requests over real TCP (mixed streaming and
//! non-streaming), and reports latency/throughput percentiles.
//!
//! ```bash
//! cargo run --release --example serve_benchmark [-- --model phi-web-38m --requests 12 --browser]
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};
use webllm::http::{ServerConfig};
use webllm::coordinator::EngineConfig;
use webllm::json::{parse, Value};
use webllm::metrics::Histogram;

fn flag(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let model = flag("--model").unwrap_or_else(|| "tiny-2m".into());
    let n_requests: usize = flag("--requests").and_then(|v| v.parse().ok()).unwrap_or(10);
    let max_tokens: usize = flag("--max-tokens").and_then(|v| v.parse().ok()).unwrap_or(16);
    let browser = std::env::args().any(|a| a == "--browser");
    let addr = "127.0.0.1:18080";

    let engine_cfg = if browser {
        EngineConfig::browser(&[&model])
    } else {
        EngineConfig::native(&[&model])
    };
    println!("booting endpoint on {addr} (model={model}, browser={browser})...");
    let server_cfg = ServerConfig {
        addr: addr.to_string(),
        engine: engine_cfg,
        max_requests: Some(n_requests),
    };
    let server = std::thread::spawn(move || webllm::http::serve(server_cfg));

    // Wait for readiness.
    let t_boot = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(mut s) => {
                let _ = write!(s, "GET /health HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n");
                let mut buf = String::new();
                let _ = s.read_to_string(&mut buf);
                if buf.contains("200 OK") {
                    break;
                }
            }
            Err(_) => {}
        }
        if t_boot.elapsed() > Duration::from_secs(600) {
            return Err("server did not become ready".into());
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    println!("endpoint ready after {:.1}s (model load + AOT compile)", t_boot.elapsed().as_secs_f64());

    // Fire concurrent clients.
    let t0 = Instant::now();
    let mut clients = Vec::new();
    for i in 0..n_requests {
        let model = model.clone();
        clients.push(std::thread::spawn(move || -> Result<(f64, usize, bool), String> {
            let stream_mode = i % 2 == 0;
            let body = format!(
                r#"{{"model":"{model}","messages":[{{"role":"user","content":"Request number {i}: say a few words about page {i}."}}],"max_tokens":{max_tokens},"seed":{i},"stream":{stream_mode}}}"#
            );
            let t = Instant::now();
            let mut s = TcpStream::connect("127.0.0.1:18080").map_err(|e| e.to_string())?;
            write!(
                s,
                "POST /v1/chat/completions HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            )
            .map_err(|e| e.to_string())?;
            let mut resp = String::new();
            s.read_to_string(&mut resp).map_err(|e| e.to_string())?;
            let elapsed = t.elapsed().as_secs_f64();
            let completion_tokens = extract_tokens(&resp, stream_mode)?;
            Ok((elapsed, completion_tokens, stream_mode))
        }));
    }

    let mut latency = Histogram::new();
    let mut total_tokens = 0usize;
    let mut failures = 0usize;
    for c in clients {
        match c.join().expect("client thread") {
            Ok((secs, toks, _)) => {
                latency.push(secs);
                total_tokens += toks;
            }
            Err(e) => {
                eprintln!("client error: {e}");
                failures += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let _ = server.join().expect("server thread");

    println!("\n=== serve_benchmark report ===");
    println!("model                 : {model}");
    println!("mode                  : {}", if browser { "browser" } else { "native" });
    println!("requests              : {n_requests} ({failures} failed)");
    println!("wall time             : {wall:.2} s");
    println!("completion tokens     : {total_tokens}");
    println!("aggregate throughput  : {:.2} tok/s", total_tokens as f64 / wall);
    println!("request latency p50   : {:.2} s", latency.percentile(50.0));
    println!("request latency p95   : {:.2} s", latency.percentile(95.0));
    println!("request latency max   : {:.2} s", latency.percentile(100.0));
    Ok(())
}

/// Pull completion-token counts out of either response form.
fn extract_tokens(raw: &str, stream_mode: bool) -> Result<usize, String> {
    let body = raw.split_once("\r\n\r\n").map(|x| x.1).unwrap_or(raw);
    if stream_mode {
        let (events, done) = webllm::http::sse_parse(body);
        if !done {
            return Err("stream did not finish".into());
        }
        let last_usage = events
            .iter()
            .rev()
            .find_map(|v: &Value| v.get("usage").cloned())
            .ok_or("no usage in stream")?;
        last_usage
            .get("completion_tokens")
            .and_then(Value::as_usize)
            .ok_or_else(|| "bad usage".into())
    } else {
        let v = parse(body.trim()).map_err(|e| format!("{e}: {body:.120}"))?;
        if let Some(err) = v.get("error") {
            return Err(webllm::json::to_string(err));
        }
        v.get("usage")
            .and_then(|u| u.get("completion_tokens"))
            .and_then(Value::as_usize)
            .ok_or_else(|| "no usage".into())
    }
}
