fn main() {
    let manifest = webllm::models::Manifest::load(&webllm::artifacts_dir()).unwrap();
    let client = webllm::runtime::thread_client().unwrap();
    for model in ["llama-web-80m", "phi-web-38m"] {
        let mut rt = webllm::runtime::ModelRuntime::load_subset(&client, &manifest, model, None, Some(&[16]), Some(&[1,8])).unwrap();
        let mc = rt.config().clone();
        let mp = mc.max_pages_per_seq();
        for b in [1usize, 8] {
            let ids = vec![5i32; b]; let pos = vec![3i32; b]; let lens = vec![4i32; b];
            let mut tables = vec![0i32; b*mp];
            for r in 0..b { tables[r*mp] = 1 + r as i32; }
            // warmup
            for _ in 0..2 { rt.decode(&ids,&pos,&lens,&tables).unwrap(); }
            let n = 10;
            let t0 = std::time::Instant::now();
            for _ in 0..n { rt.decode(&ids,&pos,&lens,&tables).unwrap(); }
            let ms = t0.elapsed().as_secs_f64()*1e3/n as f64;
            println!("{model} decode b={b}: {ms:.1} ms/step ({:.2} tok/s at b=1)", 1000.0/ms);
        }
    }
}
