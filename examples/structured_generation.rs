//! Structured generation (paper §2.1): JSON-Schema-constrained and
//! EBNF-grammar-constrained decoding through the XGrammar-analog engine.
//! Every sampled token is masked by the grammar automaton, so the output
//! is guaranteed to parse — even from an untrained model.
//!
//! ```bash
//! cargo run --release --example structured_generation
//! ```

use webllm::api::{ChatCompletionRequest, ResponseFormat};
use webllm::coordinator::{EngineConfig, ServiceWorkerMLCEngine};
use webllm::json::parse;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut engine = ServiceWorkerMLCEngine::create(EngineConfig::native(&["tiny-2m"]))?;

    // 1. JSON Schema: a tool-call-like payload.
    let schema = parse(
        r#"{
        "type": "object",
        "properties": {
            "city": {"type": "string"},
            "days": {"type": "integer"},
            "units": {"enum": ["celsius", "fahrenheit"]}
        },
        "required": ["city", "days", "units"]
    }"#,
    )?;
    let mut req = ChatCompletionRequest::new("tiny-2m")
        .system("Extract the weather query as JSON.")
        .user("What's the weather in Paris for the next 3 days, in celsius?");
    req.max_tokens = 96;
    req.sampling.seed = Some(11);
    req.response_format = ResponseFormat::JsonSchema(schema);

    let resp = engine.chat_completion(req)?;
    println!("json_schema output : {}", resp.text());
    let v = parse(resp.text()).expect("guaranteed-parseable JSON");
    println!("  parsed keys      : {:?}", v.as_object().map(|o| o.keys().cloned().collect::<Vec<_>>()));

    // 2. JSON mode: any valid JSON value.
    let mut req = ChatCompletionRequest::new("tiny-2m").user("Emit any JSON.");
    req.max_tokens = 48;
    req.sampling.seed = Some(5);
    req.response_format = ResponseFormat::JsonObject;
    let resp = engine.chat_completion(req)?;
    println!("json_object output : {}", resp.text());
    assert!(parse(resp.text()).is_ok());

    // 3. Raw EBNF grammar: a tiny command language.
    let grammar = r#"
root ::= command " " target
command ::= "open" | "close" | "toggle"
target ::= "door" | "window" | [a-z]+ "-light"
"#;
    let mut req = ChatCompletionRequest::new("tiny-2m").user("Pick an action.");
    req.max_tokens = 24;
    req.sampling.seed = Some(13);
    req.response_format = ResponseFormat::Grammar(grammar.to_string());
    let resp = engine.chat_completion(req)?;
    println!("ebnf output        : {}", resp.text());

    Ok(())
}
