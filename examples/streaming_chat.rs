//! Streaming chat: token deltas arrive over the worker message channel as
//! OpenAI-style chunks (paper §2.1 "streams back output in an OpenAI-
//! style response, which the web application can use to update the
//! frontend").
//!
//! Also demonstrates browser mode: pass `--browser` to run the engine
//! under the WebGPU/WASM cost model and compare the reported decode
//! throughput against native mode.
//!
//! ```bash
//! cargo run --release --example streaming_chat [-- --browser]
//! ```

use std::io::Write;
use webllm::api::ChatCompletionRequest;
use webllm::coordinator::{EngineConfig, ServiceWorkerMLCEngine};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let browser = std::env::args().any(|a| a == "--browser");
    let cfg = if browser {
        println!("mode: browser (WebGPU dispatch + WASM slowdown cost model)");
        EngineConfig::browser(&["tiny-2m"])
    } else {
        println!("mode: native (the MLC-LLM baseline shape)");
        EngineConfig::native(&["tiny-2m"])
    };
    let mut engine = ServiceWorkerMLCEngine::create(cfg)?;

    let turns = [
        "What can run in a web browser these days?",
        "And how do the kernels get there without CUDA?",
    ];
    let mut history: Vec<(webllm::tokenizer::Role, String)> = Vec::new();

    for user_turn in turns {
        println!("\nuser: {user_turn}");
        print!("assistant: ");
        std::io::stdout().flush()?;

        history.push((webllm::tokenizer::Role::User, user_turn.to_string()));
        let mut req = ChatCompletionRequest::new("tiny-2m")
            .system("You answer in short sentences.");
        for (role, content) in &history {
            req = req.message(*role, content.clone());
        }
        req.max_tokens = 24;
        req.sampling.temperature = 0.7;
        req.sampling.seed = Some(7);

        let mut n_chunks = 0usize;
        let resp = engine.chat_completion_stream(req, |chunk| {
            n_chunks += 1;
            print!("{}", chunk.delta);
            let _ = std::io::stdout().flush();
        })?;
        println!();
        println!(
            "  [{} chunks | {} tokens | {:.1} tok/s decode]",
            n_chunks, resp.usage.completion_tokens, resp.usage.decode_tokens_per_s
        );
        history.push((webllm::tokenizer::Role::Assistant, resp.text().to_string()));
    }

    let stats = engine.stats()?;
    println!("\nengine stats: {}", webllm::json::to_string_pretty(&stats));
    Ok(())
}
