//! Quickstart: the WebLLM "hello world" — create an engine handle, send
//! an OpenAI-style chat completion, print the reply.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! The frontend engine (`ServiceWorkerMLCEngine`) spawns a worker thread
//! that loads the model (compiles AOT HLO artifacts, uploads quantized
//! weights) and then behaves like an endpoint. Weights are synthetic
//! (seeded random, see DESIGN.md §5), so the text is gibberish — the
//! point is the full engine pipeline.

use webllm::api::ChatCompletionRequest;
use webllm::coordinator::{EngineConfig, ServiceWorkerMLCEngine};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("loading tiny-2m (compiling AOT artifacts in the worker)...");
    let mut engine = ServiceWorkerMLCEngine::create(EngineConfig::native(&["tiny-2m"]))?;
    println!("models ready: {:?}", engine.models());

    let mut request = ChatCompletionRequest::new("tiny-2m")
        .system("You are a helpful assistant running entirely on-device.")
        .user("Tell me about running language models in the browser.");
    request.max_tokens = 32;
    request.sampling.temperature = 0.8;
    request.sampling.seed = Some(42);

    let response = engine.chat_completion(request)?;
    println!("\nassistant: {}", response.text());
    println!(
        "\nusage: {} prompt + {} completion tokens | ttft {:.3}s | decode {:.1} tok/s",
        response.usage.prompt_tokens,
        response.usage.completion_tokens,
        response.usage.ttft_s,
        response.usage.decode_tokens_per_s,
    );
    Ok(())
}
