"""L2 model correctness: prefill/decode (paged, kernelized, scanned) vs the
dense full-attention reference, plus padding/batching invariants."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import TINY


@pytest.fixture(scope="module")
def setup():
    cfg = TINY
    w = M.init_weights(cfg, seed=0)
    wj = {k: jnp.asarray(v) for k, v in w.items()}
    return cfg, w, wj


def fresh_cache(cfg):
    shape = (cfg.n_layers, cfg.num_pages, cfg.page_size, cfg.n_kv_heads, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def seq_block_table(cfg, start_page, n):
    bt = np.zeros(cfg.max_pages_per_seq, np.int32)
    npages = (n + cfg.page_size - 1) // cfg.page_size
    bt[: npages + 1] = np.arange(start_page, start_page + npages + 1)
    return bt


def test_prefill_matches_dense_reference(setup):
    cfg, w, wj = setup
    rng = np.random.default_rng(1)
    for n in (1, 5, 16, 31):
        ids = rng.integers(8, 1000, n).astype(np.int32)
        ref_logits = M.ref_forward(cfg, ids, w)
        T = 32
        pad = np.zeros(T, np.int32)
        pad[:n] = ids
        kp, vp = fresh_cache(cfg)
        bt = seq_block_table(cfg, 1, n)
        logits, _, _ = M.prefill(cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n), jnp.asarray(bt), wj, kp, vp)
        np.testing.assert_allclose(np.asarray(logits), ref_logits[n - 1], rtol=1e-4, atol=1e-4)


def test_chunked_prefill_matches_whole_prompt(setup):
    # A prompt fed as positioned chunks (the scheduler's chunked prefill)
    # must produce the same last-token logits and the same cache contents
    # as one whole-prompt call — and both must match the dense reference.
    cfg, w, wj = setup
    rng = np.random.default_rng(9)
    n = 21
    ids = rng.integers(8, 1000, n).astype(np.int32)
    ref_logits = M.ref_forward(cfg, ids, w)
    bt = seq_block_table(cfg, 1, n)

    kp, vp = fresh_cache(cfg)
    pad = np.zeros(32, np.int32)
    pad[:n] = ids
    whole, wk, wv = M.prefill(
        cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n), jnp.asarray(bt), wj, kp, vp
    )

    kp, vp = fresh_cache(cfg)
    logits = None
    for start, stop in ((0, 9), (9, 16), (16, n)):
        m = stop - start
        pad = np.zeros(16, np.int32)
        pad[:m] = ids[start:stop]
        logits, kp, vp = M.prefill(
            cfg, jnp.asarray(pad), jnp.int32(start), jnp.int32(m), jnp.asarray(bt), wj, kp, vp
        )
    np.testing.assert_allclose(np.asarray(logits), ref_logits[n - 1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(whole), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(kp), np.asarray(wk), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(vp), np.asarray(wv), rtol=1e-5, atol=1e-6)


def test_decode_continues_prefill_exactly(setup):
    cfg, w, wj = setup
    rng = np.random.default_rng(2)
    n = 13
    ids = rng.integers(8, 1000, n).astype(np.int32)
    steps = [101, 202, 303]
    full = np.concatenate([ids, steps]).astype(np.int32)
    ref_logits = M.ref_forward(cfg, full, w)

    kp, vp = fresh_cache(cfg)
    pad = np.zeros(16, np.int32)
    pad[:n] = ids
    bt = seq_block_table(cfg, 1, n + len(steps))
    logits, kp, vp = M.prefill(cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n), jnp.asarray(bt), wj, kp, vp)
    np.testing.assert_allclose(np.asarray(logits), ref_logits[n - 1], rtol=1e-4, atol=1e-4)

    d_bt = np.zeros((1, cfg.max_pages_per_seq), np.int32)
    d_bt[0] = bt
    for i, tok in enumerate(steps):
        pos = n + i
        logits, kp, vp = M.decode(
            cfg,
            jnp.asarray([tok], jnp.int32),
            jnp.asarray([pos], jnp.int32),
            jnp.asarray([pos + 1], jnp.int32),
            jnp.asarray(d_bt),
            wj,
            kp,
            vp,
        )
        np.testing.assert_allclose(
            np.asarray(logits)[0], ref_logits[pos], rtol=1e-4, atol=1e-4
        )


def test_batched_decode_independent_sequences(setup):
    # Two sequences decoded together must produce the same logits as each
    # decoded alone (continuous batching must not leak state).
    cfg, w, wj = setup
    rng = np.random.default_rng(3)
    n1, n2 = 7, 11
    s1 = rng.integers(8, 1000, n1 + 1).astype(np.int32)
    s2 = rng.integers(8, 1000, n2 + 1).astype(np.int32)
    ref1 = M.ref_forward(cfg, s1, w)
    ref2 = M.ref_forward(cfg, s2, w)

    kp, vp = fresh_cache(cfg)
    bt1 = seq_block_table(cfg, 1, n1 + 1)
    bt2 = seq_block_table(cfg, 4, n2 + 1)
    pad = np.zeros(16, np.int32)
    pad[:n1] = s1[:-1]
    _, kp, vp = M.prefill(cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n1), jnp.asarray(bt1), wj, kp, vp)
    pad = np.zeros(16, np.int32)
    pad[:n2] = s2[:-1]
    _, kp, vp = M.prefill(cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n2), jnp.asarray(bt2), wj, kp, vp)

    bts = np.stack([bt1, bt2])
    logits, _, _ = M.decode(
        cfg,
        jnp.asarray([s1[-1], s2[-1]], jnp.int32),
        jnp.asarray([n1, n2], jnp.int32),
        jnp.asarray([n1 + 1, n2 + 1], jnp.int32),
        jnp.asarray(bts),
        wj,
        kp,
        vp,
    )
    np.testing.assert_allclose(np.asarray(logits)[0], ref1[n1], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(logits)[1], ref2[n2], rtol=1e-4, atol=1e-4)


def test_padding_slots_do_not_corrupt_real_pages(setup):
    # A padding slot (seq_len = 0) writes to the garbage page 0 only.
    cfg, w, wj = setup
    rng = np.random.default_rng(4)
    n = 9
    ids = rng.integers(8, 1000, n + 1).astype(np.int32)
    ref_logits = M.ref_forward(cfg, ids, w)

    kp, vp = fresh_cache(cfg)
    bt = seq_block_table(cfg, 1, n + 1)
    pad = np.zeros(16, np.int32)
    pad[:n] = ids[:-1]
    _, kp, vp = M.prefill(cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n), jnp.asarray(bt), wj, kp, vp)

    bts = np.zeros((2, cfg.max_pages_per_seq), np.int32)
    bts[0] = bt
    logits, _, _ = M.decode(
        cfg,
        jnp.asarray([ids[-1], 999], jnp.int32),
        jnp.asarray([n, 0], jnp.int32),
        jnp.asarray([n + 1, 0], jnp.int32),
        jnp.asarray(bts),
        wj,
        kp,
        vp,
    )
    np.testing.assert_allclose(np.asarray(logits)[0], ref_logits[n], rtol=1e-4, atol=1e-4)


def test_decode_gather_schedule_matches_default(setup):
    cfg, w, wj = setup
    rng = np.random.default_rng(5)
    n = 6
    ids = rng.integers(8, 1000, n).astype(np.int32)
    kp, vp = fresh_cache(cfg)
    bt = seq_block_table(cfg, 1, n + 1)
    pad = np.zeros(16, np.int32)
    pad[:n] = ids
    _, kp, vp = M.prefill(cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n), jnp.asarray(bt), wj, kp, vp)
    d_bt = np.zeros((1, cfg.max_pages_per_seq), np.int32)
    d_bt[0] = bt
    args = (
        jnp.asarray([42], jnp.int32),
        jnp.asarray([n], jnp.int32),
        jnp.asarray([n + 1], jnp.int32),
        jnp.asarray(d_bt),
        wj,
        kp,
        vp,
    )
    a, _, _ = M.decode(cfg, *args, attention_schedule="paged_loop")
    b, _, _ = M.decode(cfg, *args, attention_schedule="gather")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_weight_specs_cover_init(setup):
    cfg, w, _ = setup
    names = {n for n, _, _ in M.weight_specs(cfg)}
    assert names == set(w.keys())


def test_rope_position_sensitivity():
    # Same token at different positions must produce different K.
    cfg = TINY
    x = jnp.ones((2, cfg.n_heads, cfg.head_dim), jnp.float32)
    a = M._rope(x, jnp.asarray([3, 3], jnp.int32), cfg.rope_theta)
    b = M._rope(x, jnp.asarray([3, 7], jnp.int32), cfg.rope_theta)
    assert np.allclose(np.asarray(a)[0], np.asarray(b)[0])
    assert not np.allclose(np.asarray(a)[1], np.asarray(b)[1])


def test_rope_preserves_norm():
    cfg = TINY
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((5, cfg.n_heads, cfg.head_dim)), jnp.float32)
    y = M._rope(x, jnp.arange(5, dtype=jnp.int32), cfg.rope_theta)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_backend_schedules_agree(setup):
    # Every (layer_mode, attention, q4) artifact specialization must be
    # semantically identical to the reference configuration.
    cfg, w, wj = setup
    rng = np.random.default_rng(7)
    n = 9
    ids = rng.integers(8, 1000, n).astype(np.int32)
    kp, vp = fresh_cache(cfg)
    bt = seq_block_table(cfg, 1, n + 1)
    pad = np.zeros(16, np.int32)
    pad[:n] = ids
    _, kp, vp = M.prefill(cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n), jnp.asarray(bt), wj, kp, vp)
    d_bt = np.zeros((2, cfg.max_pages_per_seq), np.int32)
    d_bt[0] = bt
    args = (
        jnp.asarray([42, 0], jnp.int32),
        jnp.asarray([n, 0], jnp.int32),
        jnp.asarray([n + 1, 0], jnp.int32),
        jnp.asarray(d_bt),
        wj,
        kp,
        vp,
    )
    base, bk, bv = M.decode(cfg, *args)
    for attention in ("paged_loop", "gather"):
        for q4 in ("tiled", "single"):
            for mode in ("scan", "unroll"):
                got, gk, gv = M.decode(
                    cfg, *args,
                    attention_schedule=attention, q4_schedule=q4, layer_mode=mode,
                )
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(base), rtol=1e-4, atol=1e-5,
                    err_msg=f"{attention}/{q4}/{mode}",
                )
                np.testing.assert_allclose(
                    np.asarray(gk), np.asarray(bk), rtol=1e-5, atol=1e-6,
                    err_msg=f"{attention}/{q4}/{mode} k_pages",
                )


def test_prefill_q4_single_matches_tiled(setup):
    cfg, w, wj = setup
    rng = np.random.default_rng(8)
    n = 11
    ids = rng.integers(8, 1000, n).astype(np.int32)
    pad = np.zeros(16, np.int32)
    pad[:n] = ids
    bt = seq_block_table(cfg, 1, n)
    kp, vp = fresh_cache(cfg)
    a, _, _ = M.prefill(cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n), jnp.asarray(bt), wj, kp, vp,
                        q4_schedule="tiled")
    b, _, _ = M.prefill(cfg, jnp.asarray(pad), jnp.int32(0), jnp.int32(n), jnp.asarray(bt), wj, kp, vp,
                        q4_schedule="single")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
