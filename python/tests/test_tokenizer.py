"""Tokenizer trainer properties (the Rust encoder mirrors encode())."""

import json
import os

import pytest
from hypothesis import given, settings, strategies as st

from compile.tokenizer_gen import (
    BYTE_OFFSET,
    FIRST_MERGE_ID,
    SPECIALS,
    build_tokenizer,
    decode,
    encode,
    token_bytes,
)

SET = dict(deadline=None, max_examples=40)


@pytest.fixture(scope="module")
def tok():
    return build_tokenizer(vocab_size=4096)


@settings(**SET)
@given(st.text(min_size=0, max_size=200))
def test_roundtrip_any_text(text):
    tok = _TOK
    assert decode(tok, encode(tok, text)) == text


@settings(**SET)
@given(st.binary(min_size=1, max_size=64))
def test_roundtrip_binaryish(data):
    tok = _TOK
    text = data.decode("utf-8", errors="replace")
    assert decode(tok, encode(tok, text)) == text


def test_specials_reserved(tok):
    assert SPECIALS["<pad>"] == 0
    assert max(SPECIALS.values()) < BYTE_OFFSET
    for m in tok["merges"]:
        assert m[0] >= BYTE_OFFSET and m[1] >= BYTE_OFFSET


def test_merges_reference_earlier_ids_only(tok):
    for i, (a, b) in enumerate(tok["merges"]):
        assert a < FIRST_MERGE_ID + i
        assert b < FIRST_MERGE_ID + i


def test_compression_on_corpus_text(tok):
    text = "The engine streams tokens back to the application."
    ids = encode(tok, text)
    assert len(ids) < len(text.encode()) * 0.5  # BPE actually compresses


def test_token_bytes_consistent(tok):
    table = token_bytes([tuple(m) for m in tok["merges"]])
    assert table[BYTE_OFFSET + ord("a")] == b"a"
    # every merged token's bytes are the concat of its parts
    for i, (a, b) in enumerate(tok["merges"]):
        assert table[FIRST_MERGE_ID + i] == table[a] + table[b]


def test_artifact_tokenizer_loadable():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/tokenizer.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        t = json.load(f)
    assert t["vocab_size"] == 4096
    assert decode(t, encode(t, "hello world")) == "hello world"


_TOK = build_tokenizer(vocab_size=4096)
