"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the GQA/MHA axis) per the repro contract;
all kernels run under interpret=True on CPU.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    paged_attention_decode,
    prefill_attention,
    q4_matmul,
    rmsnorm,
)
from compile.kernels import ref
from compile.kernels.ref import GROUP_SIZE, PACK

SET = dict(deadline=None, max_examples=25)


def rng_for(*dims) -> np.random.Generator:
    return np.random.default_rng(hash(dims) % 2**31)


# ---------------------------------------------------------------------------
# q4_matmul
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    m=st.integers(1, 16),
    k_groups=st.integers(1, 8),
    n=st.sampled_from([8, 16, 64, 96, 128, 256, 512]),
)
def test_q4_matmul_matches_ref(m, k_groups, n):
    k = k_groups * GROUP_SIZE
    rng = rng_for(m, k, n)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wp = jnp.asarray(rng.integers(0, 2**32, (k // PACK, n), dtype=np.uint32))
    ws = jnp.asarray((rng.standard_normal((k // GROUP_SIZE, n)) * 0.05).astype(np.float32))
    got = np.asarray(q4_matmul(x, wp, ws))
    want = np.asarray(ref.q4_matmul(x, wp, ws))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_q4_matmul_exact_on_integer_scales():
    # Integer x and power-of-two scales make the product exactly
    # representable: fused kernel must be bit-identical to the oracle.
    rng = rng_for(7)
    k, n = 128, 64
    x = jnp.asarray(rng.integers(-4, 5, (3, k)).astype(np.float32))
    wp = jnp.asarray(rng.integers(0, 2**32, (k // PACK, n), dtype=np.uint32))
    ws = jnp.full((k // GROUP_SIZE, n), 0.25, jnp.float32)
    got = np.asarray(q4_matmul(x, wp, ws))
    want = np.asarray(ref.q4_matmul(x, wp, ws))
    assert (got == want).all()


def test_q4_matmul_rejects_bad_pack():
    x = jnp.zeros((1, 64), jnp.float32)
    wp = jnp.zeros((9, 8), jnp.uint32)  # 9*8 != 64
    ws = jnp.zeros((1, 8), jnp.float32)
    with pytest.raises(AssertionError):
        q4_matmul(x, wp, ws)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@settings(**SET)
@given(t=st.integers(1, 40), d=st.sampled_from([8, 32, 96, 128, 768]))
def test_rmsnorm_matches_ref(t, d):
    rng = rng_for(t, d)
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    got = np.asarray(rmsnorm(x, w))
    want = np.asarray(ref.rmsnorm(x, w))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rmsnorm_scale_invariance():
    # RMSNorm(a * x) == RMSNorm(x) for a > 0 (eps is negligible here).
    rng = rng_for(11)
    x = jnp.asarray(rng.standard_normal((4, 64)) + 1.0, jnp.float32)
    w = jnp.ones((64,), jnp.float32)
    a = np.asarray(rmsnorm(x, w))
    b = np.asarray(rmsnorm(x * 16.0, w))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# prefill attention
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    t=st.sampled_from([8, 16, 32, 64]),
    heads=st.sampled_from([(4, 4), (4, 2), (8, 4), (8, 1), (12, 4)]),
    dh=st.sampled_from([16, 32, 64]),
    frac=st.floats(0.1, 1.0),
)
def test_prefill_attention_matches_ref(t, heads, dh, frac):
    h, kvh = heads
    seq_len = max(1, int(t * frac))
    rng = rng_for(t, h, kvh, dh, seq_len)
    q = jnp.asarray(rng.standard_normal((t, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((t, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((t, kvh, dh)), jnp.float32)
    got = np.asarray(prefill_attention(q, k, v, jnp.int32(seq_len)))
    want = np.asarray(ref.prefill_attention(q, k, v, seq_len))
    # Compare only valid rows; padding rows are unconstrained.
    np.testing.assert_allclose(got[:seq_len], want[:seq_len], rtol=1e-4, atol=1e-4)


def test_prefill_attention_first_token_is_v():
    # Causal: the first token attends only to itself -> output == v[0].
    rng = rng_for(3)
    t, h, dh = 8, 4, 16
    q = jnp.asarray(rng.standard_normal((t, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((t, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((t, h, dh)), jnp.float32)
    out = np.asarray(prefill_attention(q, k, v, jnp.int32(t)))
    np.testing.assert_allclose(out[0], np.asarray(v)[0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# paged attention (both schedules)
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    b=st.integers(1, 8),
    heads=st.sampled_from([(4, 4), (4, 2), (8, 4), (8, 8), (12, 4)]),
    dh=st.sampled_from([16, 32, 64]),
    page=st.sampled_from([8, 16]),
    max_pages=st.integers(1, 6),
    schedule=st.sampled_from(["paged_loop", "gather"]),
    data=st.data(),
)
def test_paged_attention_matches_ref(b, heads, dh, page, max_pages, schedule, data):
    h, kvh = heads
    p_total = max_pages * 4 + 1
    rng = rng_for(b, h, kvh, dh, page, max_pages)
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((p_total, page, kvh, dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((p_total, page, kvh, dh)), jnp.float32)
    bt = jnp.asarray(rng.integers(1, p_total, (b, max_pages), dtype=np.int32))
    lens = data.draw(
        st.lists(st.integers(0, max_pages * page), min_size=b, max_size=b)
    )
    sl = jnp.asarray(np.array(lens, np.int32))
    got = np.asarray(paged_attention_decode(q, kp, vp, bt, sl, schedule=schedule))
    want = np.asarray(ref.paged_attention_decode(q, kp, vp, bt, sl))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_paged_attention_schedules_agree():
    rng = rng_for(42)
    b, h, kvh, dh, page, mp, p = 4, 8, 4, 32, 16, 4, 17
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((p, page, kvh, dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((p, page, kvh, dh)), jnp.float32)
    bt = jnp.asarray(rng.integers(1, p, (b, mp), dtype=np.int32))
    sl = jnp.asarray([1, 17, 64, 33], np.int32)
    a = np.asarray(paged_attention_decode(q, kp, vp, bt, sl, schedule="paged_loop"))
    g = np.asarray(paged_attention_decode(q, kp, vp, bt, sl, schedule="gather"))
    np.testing.assert_allclose(a, g, rtol=1e-4, atol=1e-5)


def test_paged_attention_zero_len_is_zero():
    rng = rng_for(5)
    b, h, kvh, dh, page, mp, p = 2, 4, 2, 16, 8, 2, 5
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((p, page, kvh, dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((p, page, kvh, dh)), jnp.float32)
    bt = jnp.zeros((b, mp), jnp.int32)
    sl = jnp.zeros((b,), jnp.int32)
    for sched in ("paged_loop", "gather"):
        out = np.asarray(paged_attention_decode(q, kp, vp, bt, sl, schedule=sched))
        assert (out == 0).all(), sched


def test_paged_attention_ignores_pages_beyond_len():
    # Garbage in pages past seq_len must not affect the output.
    rng = rng_for(6)
    b, h, kvh, dh, page, mp, p = 1, 4, 4, 16, 8, 4, 9
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    kp = np.asarray(rng.standard_normal((p, page, kvh, dh)), np.float32)
    vp = np.asarray(rng.standard_normal((p, page, kvh, dh)), np.float32)
    bt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    sl = jnp.asarray([9], jnp.int32)  # only pages 1 and 2 used
    base = np.asarray(paged_attention_decode(q, jnp.asarray(kp), jnp.asarray(vp), bt, sl))
    kp[3:] = 1e6
    vp[3:] = -1e6
    poisoned = np.asarray(
        paged_attention_decode(q, jnp.asarray(kp), jnp.asarray(vp), bt, sl)
    )
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)
