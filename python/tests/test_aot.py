"""AOT artifact integrity: manifest schema, weight layout, HLO text shape.

These tests validate the *contract* between aot.py and the Rust runtime
(rust/src/runtime/artifact.rs): argument order, offsets, dtypes.
"""

import json
import os

import numpy as np
import pytest

from compile import model as M
from compile.configs import ALL_CONFIGS, TINY
from compile.aot import lower_decode, lower_prefill

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../artifacts"))


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_all_models(manifest):
    assert set(manifest["models"]) == set(ALL_CONFIGS)


def test_weight_entries_match_specs(manifest):
    for name, entry in manifest["models"].items():
        cfg = ALL_CONFIGS[name]
        specs = M.weight_specs(cfg)
        assert [e["name"] for e in entry["weights"]] == [n for n, _, _ in specs]
        for e, (n, shape, ty) in zip(entry["weights"], specs):
            assert tuple(e["shape"]) == shape
            assert e["dtype"] == ty
            itemsize = 4  # f32/u32/i32 all 4 bytes
            assert e["nbytes"] == int(np.prod(shape)) * itemsize
            assert e["offset"] % 64 == 0


def test_weights_bin_size(manifest):
    for name, entry in manifest["models"].items():
        path = os.path.join(ART, entry["weights_bin"])
        last = entry["weights"][-1]
        assert os.path.getsize(path) == last["offset"] + last["nbytes"]


def test_hlo_files_exist_and_are_entry_modules(manifest):
    for name, entry in manifest["models"].items():
        cfg = ALL_CONFIGS[name]
        assert set(entry["prefill"]) == {str(c) for c in cfg.prefill_chunks}
        assert set(entry["decode"]) == {str(b) for b in cfg.decode_batches}
        for phase in ("prefill", "decode"):
            for sub in entry[phase].values():
                path = os.path.join(ART, sub["path"])
                assert os.path.exists(path), path
                with open(path) as f:
                    text = f.read()
                assert "ENTRY" in text and text.startswith("HloModule"), path


def test_prefill_param_count_matches_manifest(manifest):
    name = TINY.name
    entry = manifest["models"][name]
    cfg = ALL_CONFIGS[name]
    n_weights = len(entry["weights"])
    chunk = cfg.prefill_chunks[0]
    hlo = lower_prefill(cfg, chunk)
    # parameter count = phase inputs + weights + 2 caches
    n_inputs = len(entry["prefill"][str(chunk)]["inputs"])
    expected = n_inputs + n_weights + 2
    assert hlo.count("parameter(") >= expected


def test_decode_batch_shapes_in_hlo(manifest):
    cfg = ALL_CONFIGS[TINY.name]
    b = cfg.decode_batches[-1]
    hlo = lower_decode(cfg, b)
    assert f"s32[{b}]" in hlo  # ids / positions / seq_lens params
    assert f"f32[{b},{cfg.vocab_size}]" in hlo  # logits output


def test_cache_spec_shape(manifest):
    for name, entry in manifest["models"].items():
        cfg = ALL_CONFIGS[name]
        for c in entry["cache"]:
            assert tuple(c["shape"]) == (
                cfg.n_layers,
                cfg.num_pages,
                cfg.page_size,
                cfg.n_kv_heads,
                cfg.head_dim,
            )


def test_manifest_constants(manifest):
    assert manifest["group_size"] == 64
    assert manifest["pack"] == 8
    assert manifest["attention_schedule"] == "gather"
    assert manifest["outputs"] if "outputs" in manifest else True
