"""Quantization pack/unpack properties."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quantize import dequantize_q4, quantize_q4
from compile.kernels.ref import GROUP_SIZE, PACK
import jax.numpy as jnp

SET = dict(deadline=None, max_examples=25)


@settings(**SET)
@given(kg=st.integers(1, 6), n=st.integers(1, 64), scale=st.floats(0.01, 10.0))
def test_roundtrip_error_bound(kg, n, scale):
    k = kg * GROUP_SIZE
    rng = np.random.default_rng(kg * 1000 + n)
    w = (rng.standard_normal((k, n)) * scale).astype(np.float32)
    packed, scales = quantize_q4(w)
    deq = dequantize_q4(packed, scales)
    # Max quantization error is scale/2 per element; scale = absmax/7.
    group_absmax = np.abs(w.reshape(-1, GROUP_SIZE, n)).max(axis=1, keepdims=True)
    bound = np.repeat(group_absmax / 7.0 / 2.0, GROUP_SIZE, axis=1).reshape(k, n)
    assert (np.abs(deq - w) <= bound + 1e-6).all()


@settings(**SET)
@given(kg=st.integers(1, 4), n=st.integers(1, 32))
def test_quantized_values_are_fixed_point(kg, n):
    # Quantize(dequantize(q)) is idempotent: codes survive a roundtrip.
    k = kg * GROUP_SIZE
    rng = np.random.default_rng(kg * 77 + n)
    w = rng.standard_normal((k, n)).astype(np.float32)
    p1, s1 = quantize_q4(w)
    p2, s2 = quantize_q4(dequantize_q4(p1, s1))
    assert (p1 == p2).all()
    np.testing.assert_allclose(s1, s2, rtol=1e-5)


def test_packing_layout_matches_jnp_ref():
    # numpy packer and the jnp dequant used by kernels must agree bit-for-bit.
    from compile.kernels import ref

    rng = np.random.default_rng(0)
    k, n = 2 * GROUP_SIZE, 24
    w = rng.standard_normal((k, n)).astype(np.float32)
    packed, scales = quantize_q4(w)
    np_deq = dequantize_q4(packed, scales)
    jnp_deq = np.asarray(ref.dequant_q4(jnp.asarray(packed), jnp.asarray(scales)))
    np.testing.assert_allclose(np_deq, jnp_deq, rtol=0, atol=0)


def test_all_16_codes_reachable():
    w = np.linspace(-7, 7, GROUP_SIZE)[:, None].astype(np.float32)
    packed, scales = quantize_q4(w)
    codes = []
    for i in range(PACK):
        codes.extend(((packed >> np.uint32(4 * i)) & np.uint32(0xF)).ravel())
    assert set(np.asarray(codes).tolist()) >= set(range(1, 16))
