"""Layer-2 JAX model: Llama-architecture decoder over the Pallas kernels.

This is the compute graph MLC-LLM would compile to WebGPU; here it lowers
(once, at build time) to HLO text that the Rust runtime compiles with the
PJRT CPU client. Two entry points, both with fully static shapes, mirroring
the static-shape discipline TVM imposes on WebLLM's WebGPU artifacts:

  * ``prefill``  — one sequence, one padded *positioned* chunk of T
    tokens at absolute positions start_pos..start_pos+n. Writes the
    chunk's K/V into the sequence's pages, attends over the pool-resident
    full prefix (earlier chunks / prefix-cache pages included), and
    returns the last valid token's logits.
  * ``decode``   — B sequences, one token each (continuous-batching step).
    Appends each token's K/V to its page and runs PagedAttention.

The transformer layer stack runs under ``lax.scan`` with weights stacked on
a leading layer axis — this keeps the lowered HLO (and the Rust-side
argument marshalling) small: ~20 arrays instead of ~20 * n_layers.

Weights are group-quantized 4-bit (see quantize.py); every matmul goes
through the fused dequant-GEMM Pallas kernel. The KV cache is a paged pool
(functional: passed in, returned updated) managed by the Rust kvcache
module. Page 0 is reserved as the garbage page: padding slots write there.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import chunk_prefill_attention, paged_attention_decode, q4_matmul, rmsnorm
from .kernels.ref import GROUP_SIZE, PACK
from .quantize import quantize_q4

Array = jnp.ndarray

# ---------------------------------------------------------------------------
# Parameter schema
# ---------------------------------------------------------------------------

# Weight arrays, in the canonical order shared with aot.py's manifest and
# the Rust runtime (models/weights.rs). Stacked on a leading n_layers axis
# where noted. (name, kind) with kind in {f32, u32}.


def weight_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], str]]:
    d, f, v = cfg.d_model, cfg.ffn_dim, cfg.vocab_size
    qd, kvd, l = cfg.q_dim, cfg.kv_dim, cfg.n_layers
    g = GROUP_SIZE

    def q4(name: str, k: int, n: int, stacked: bool = True):
        lead = (l,) if stacked else ()
        return [
            (f"{name}_packed", lead + (k // PACK, n), "u32"),
            (f"{name}_scales", lead + (k // g, n), "f32"),
        ]

    specs: List[Tuple[str, Tuple[int, ...], str]] = []
    specs.append(("embed", (v, d), "f32"))
    specs.append(("attn_norm", (l, d), "f32"))
    specs += q4("wq", d, qd)
    specs += q4("wk", d, kvd)
    specs += q4("wv", d, kvd)
    specs += q4("wo", qd, d)
    specs.append(("mlp_norm", (l, d), "f32"))
    specs += q4("wgate", d, f)
    specs += q4("wup", d, f)
    specs += q4("wdown", f, d)
    specs.append(("final_norm", (d,), "f32"))
    specs += q4("lm_head", d, v, stacked=False)
    return specs


def cache_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], str]]:
    shape = (cfg.n_layers, cfg.num_pages, cfg.page_size, cfg.n_kv_heads, cfg.head_dim)
    return [("k_pages", shape, "f32"), ("v_pages", shape, "f32")]


def init_weights(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Seeded synthetic weights, quantized to q4 where the schema says so.

    GPT-2-style init scales keep logits in a sane range so sampling and
    the grammar-constrained path behave like a real (if untrained) model.
    """
    rng = np.random.default_rng(seed)
    d, f, v = cfg.d_model, cfg.ffn_dim, cfg.vocab_size
    qd, kvd, l = cfg.q_dim, cfg.kv_dim, cfg.n_layers
    std = 0.02
    resid_std = std / np.sqrt(2 * l)

    def mat(k: int, n: int, s: float) -> np.ndarray:
        return (rng.standard_normal((k, n)) * s).astype(np.float32)

    out: Dict[str, np.ndarray] = {}
    out["embed"] = mat(v, d, std)
    out["attn_norm"] = np.ones((l, d), np.float32)
    out["mlp_norm"] = np.ones((l, d), np.float32)
    out["final_norm"] = np.ones((d,), np.float32)

    def q4_stack(name: str, k: int, n: int, s: float) -> None:
        packed = np.empty((l, k // PACK, n), np.uint32)
        scales = np.empty((l, k // GROUP_SIZE, n), np.float32)
        for i in range(l):
            packed[i], scales[i] = quantize_q4(mat(k, n, s))
        out[f"{name}_packed"] = packed
        out[f"{name}_scales"] = scales

    q4_stack("wq", d, qd, std)
    q4_stack("wk", d, kvd, std)
    q4_stack("wv", d, kvd, std)
    q4_stack("wo", qd, d, resid_std)
    q4_stack("wgate", d, f, std)
    q4_stack("wup", d, f, std)
    q4_stack("wdown", f, d, resid_std)
    p, s = quantize_q4(mat(d, v, std))
    out["lm_head_packed"], out["lm_head_scales"] = p, s

    for name, shape, ty in weight_specs(cfg):
        assert out[name].shape == shape, (name, out[name].shape, shape)
        assert str(out[name].dtype) == {"f32": "float32", "u32": "uint32"}[ty]
    return out


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def _rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding, half-rotation convention.

    x: [T, H, Dh]; positions: i32[T] -> same shape out.
    """
    dh = x.shape[-1]
    half = dh // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * inv_freq[None, :]  # [T, half]
    cos = jnp.cos(ang)[:, None, :]  # [T, 1, half]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _layer(
    cfg: ModelConfig,
    x: Array,
    lw: Dict[str, Array],
    positions: Array,
    attend,
    q4_schedule: str = "tiled",
) -> Array:
    """One transformer layer body, shared by prefill and decode.

    x: [T, D]; ``attend(q, k, v) -> [T, H, Dh]`` is phase-specific (and owns
    the cache write). Returns the new residual stream.
    """
    t = x.shape[0]
    mm = lambda a, name: q4_matmul(
        a, lw[f"{name}_packed"], lw[f"{name}_scales"], schedule=q4_schedule
    )
    h = rmsnorm(x, lw["attn_norm"], eps=cfg.norm_eps)
    q = mm(h, "wq").reshape(t, cfg.n_heads, cfg.head_dim)
    k = mm(h, "wk").reshape(t, cfg.n_kv_heads, cfg.head_dim)
    v = mm(h, "wv").reshape(t, cfg.n_kv_heads, cfg.head_dim)
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)
    att = attend(q, k, v).reshape(t, cfg.q_dim)
    x = x + mm(att, "wo")

    h = rmsnorm(x, lw["mlp_norm"], eps=cfg.norm_eps)
    act = jax.nn.silu(mm(h, "wgate")) * mm(h, "wup")
    x = x + mm(act, "wdown")
    return x


_LAYER_KEYS = [
    "attn_norm",
    "wq_packed", "wq_scales",
    "wk_packed", "wk_scales",
    "wv_packed", "wv_scales",
    "wo_packed", "wo_scales",
    "mlp_norm",
    "wgate_packed", "wgate_scales",
    "wup_packed", "wup_scales",
    "wdown_packed", "wdown_scales",
]


def _stacked_layer_tree(weights: Dict[str, Array]) -> Dict[str, Array]:
    return {k: weights[k] for k in _LAYER_KEYS}


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    ids: Array,          # i32[T]           padded token ids (n valid)
    start_pos: Array,    # i32[]            absolute position of ids[0]
    n: Array,            # i32[]            valid tokens in this chunk (<= T)
    block_table: Array,  # i32[max_pages]   pages allocated to this sequence
    weights: Dict[str, Array],
    k_pages: Array,      # f32[L, P, page, KVH, Dh]
    v_pages: Array,
    q4_schedule: str = "tiled",
) -> Tuple[Array, Array, Array]:
    """Run one *positioned* prompt chunk; returns (last-valid-token logits
    [V], new caches).

    The chunk's n tokens occupy absolute positions start_pos..start_pos+n
    of the sequence. Each layer writes the chunk's K/V into the
    sequence's pages, then attends over the **pool-resident full prefix**
    [0, start_pos + n) through the block table (chunk_prefill_attention),
    so positions written by earlier chunks — or reused verbatim from a
    prefix-cache hit — participate without recompute. start_pos == 0,
    n == prompt length is whole-prompt prefill.
    """
    t = ids.shape[0]
    pg = cfg.page_size
    rel = jax.lax.iota(jnp.int32, t)
    positions = start_pos + rel  # absolute positions (rope + paging)
    valid = rel < n

    x = weights["embed"][ids]  # [T, D]

    # Where each chunk position's K/V lands: its sequence page, or the
    # garbage page 0 when padding.
    page_ids = jnp.where(valid, block_table[positions // pg], 0)  # i32[T]
    offsets = positions % pg

    def body(x, layer_in):
        lw, kp, vp = layer_in  # kp/vp: [P, page, KVH, Dh]

        def attend(q, k, v):
            nonlocal kp, vp
            kp = kp.at[page_ids, offsets].set(k)
            vp = vp.at[page_ids, offsets].set(v)
            return chunk_prefill_attention(q, kp, vp, block_table, start_pos, n)

        x = _layer(cfg, x, lw, positions, attend, q4_schedule=q4_schedule)
        return x, (kp, vp)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (_stacked_layer_tree(weights), k_pages, v_pages)
    )

    x = rmsnorm(x, weights["final_norm"], eps=cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, n - 1, 1, axis=0)  # [1, D]
    logits = q4_matmul(
        last, weights["lm_head_packed"], weights["lm_head_scales"], schedule=q4_schedule
    )[0]
    return logits, k_new, v_new


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode(
    cfg: ModelConfig,
    ids: Array,           # i32[B]             current token per sequence
    positions: Array,     # i32[B]             its position (seq_len - 1)
    seq_lens: Array,      # i32[B]             0 => padding slot
    block_tables: Array,  # i32[B, max_pages]
    weights: Dict[str, Array],
    k_pages: Array,       # f32[L, P, page, KVH, Dh]
    v_pages: Array,
    attention_schedule: str = "paged_loop",
    q4_schedule: str = "tiled",
    layer_mode: str = "scan",
) -> Tuple[Array, Array, Array]:
    """One continuous-batching decode step; returns (logits [B, V], caches).

    layer_mode:
      * "scan"   — layers under ``lax.scan`` (small HLO; best for larger
        batches on XLA:CPU).
      * "unroll" — layers inlined (XLA:CPU elides the scan's per-iteration
        cache-carry copies; measured 2.6x at batch=1 — EXPERIMENTS.md
        §Perf). aot.py picks per compiled batch size.
    """
    b = ids.shape[0]
    pg = cfg.page_size
    valid = seq_lens > 0

    x = weights["embed"][ids]  # [B, D]

    batch_idx = jax.lax.iota(jnp.int32, b)
    page_ids = jnp.where(valid, block_tables[batch_idx, positions // pg], 0)
    offsets = positions % pg

    if layer_mode == "unroll":
        kp_all, vp_all = k_pages, v_pages
        for l in range(cfg.n_layers):
            lw = {k: weights[k][l] for k in _LAYER_KEYS}

            def attend(q, k, v, l=l):
                nonlocal kp_all, vp_all
                kp_all = kp_all.at[l, page_ids, offsets].set(k)
                vp_all = vp_all.at[l, page_ids, offsets].set(v)
                return paged_attention_decode(
                    q, kp_all[l], vp_all[l], block_tables, seq_lens,
                    schedule=attention_schedule,
                )

            x = _layer(cfg, x, lw, positions, attend, q4_schedule=q4_schedule)
        k_new, v_new = kp_all, vp_all
    else:
        def body(x, layer_in):
            lw, kp, vp = layer_in

            def attend(q, k, v):
                nonlocal kp, vp
                kp = kp.at[page_ids, offsets].set(k)
                vp = vp.at[page_ids, offsets].set(v)
                return paged_attention_decode(
                    q, kp, vp, block_tables, seq_lens, schedule=attention_schedule
                )

            x = _layer(cfg, x, lw, positions, attend, q4_schedule=q4_schedule)
            return x, (kp, vp)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (_stacked_layer_tree(weights), k_pages, v_pages)
        )

    x = rmsnorm(x, weights["final_norm"], eps=cfg.norm_eps)
    logits = q4_matmul(
        x, weights["lm_head_packed"], weights["lm_head_scales"], schedule=q4_schedule
    )
    return logits, k_new, v_new


# ---------------------------------------------------------------------------
# Full-attention reference (no paging, no kernels) for numeric validation
# ---------------------------------------------------------------------------


def ref_forward(cfg: ModelConfig, ids: np.ndarray, weights: Dict[str, np.ndarray]) -> np.ndarray:
    """Dense reference forward over a whole sequence; returns logits [T, V].

    Uses the jnp oracles only (ref.q4_matmul etc.) — no Pallas, no paging —
    so prefill/decode consistency tests have an independent ground truth.
    """
    from .kernels import ref as R

    t = len(ids)
    positions = jnp.arange(t, dtype=jnp.int32)
    x = jnp.asarray(weights["embed"])[jnp.asarray(ids)]
    for l in range(cfg.n_layers):
        lw = {k: jnp.asarray(weights[k][l]) for k in _LAYER_KEYS}
        h = R.rmsnorm(x, lw["attn_norm"], eps=cfg.norm_eps)
        q = R.q4_matmul(h, lw["wq_packed"], lw["wq_scales"]).reshape(t, cfg.n_heads, cfg.head_dim)
        k = R.q4_matmul(h, lw["wk_packed"], lw["wk_scales"]).reshape(t, cfg.n_kv_heads, cfg.head_dim)
        v = R.q4_matmul(h, lw["wv_packed"], lw["wv_scales"]).reshape(t, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)
        att = R.prefill_attention(q, k, v, t).reshape(t, cfg.q_dim)
        x = x + R.q4_matmul(att, lw["wo_packed"], lw["wo_scales"])
        h = R.rmsnorm(x, lw["mlp_norm"], eps=cfg.norm_eps)
        act = jax.nn.silu(R.q4_matmul(h, lw["wgate_packed"], lw["wgate_scales"])) * R.q4_matmul(
            h, lw["wup_packed"], lw["wup_scales"]
        )
        x = x + R.q4_matmul(act, lw["wdown_packed"], lw["wdown_scales"])
    x = R.rmsnorm(x, jnp.asarray(weights["final_norm"]), eps=cfg.norm_eps)
    return np.asarray(
        R.q4_matmul(x, jnp.asarray(weights["lm_head_packed"]), jnp.asarray(weights["lm_head_scales"]))
    )
