"""Group-wise 4-bit weight quantization ("q4" — MLC's q4f32 analog).

Produces the "converted weights" artifact of the paper's pipeline: each
[K, N] weight matrix becomes a packed u32[K//8, N] nibble tensor plus a
f32[K//G, N] scale tensor (G = 64, along the reduction dim). Dequant is
w = (q - 8) * scale, matching kernels/ref.py and the fused Pallas GEMM.
"""

from __future__ import annotations

import numpy as np

from .kernels.ref import GROUP_SIZE, PACK


def quantize_q4(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Quantize f32[K, N] -> (u32[K//8, N] packed, f32[K//G, N] scales)."""
    k, n = w.shape
    assert k % GROUP_SIZE == 0 and k % PACK == 0, (k, n)
    g = k // GROUP_SIZE
    grouped = w.reshape(g, GROUP_SIZE, n)
    absmax = np.abs(grouped).max(axis=1)  # [G, N]
    scales = (absmax / 7.0).astype(np.float32)
    scales = np.maximum(scales, 1e-8)
    q = np.rint(grouped / scales[:, None, :]).astype(np.int32) + 8
    q = np.clip(q, 0, 15).astype(np.uint32).reshape(k, n)

    words = q.reshape(k // PACK, PACK, n)
    packed = np.zeros((k // PACK, n), dtype=np.uint32)
    for i in range(PACK):
        packed |= words[:, i, :] << np.uint32(4 * i)
    return packed, scales


def dequantize_q4(packed: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Inverse of quantize_q4 (up to rounding): f32[K, N]."""
    k8, n = packed.shape
    k = k8 * PACK
    q = np.zeros((k8, PACK, n), dtype=np.uint32)
    for i in range(PACK):
        q[:, i, :] = (packed >> np.uint32(4 * i)) & np.uint32(0xF)
    q = q.reshape(k, n).astype(np.float32) - 8.0
    return q * np.repeat(scales, GROUP_SIZE, axis=0)
