"""Byte-level BPE tokenizer trainer (build-time).

WebLLM ships HuggingFace tokenizers compiled to WASM; the Rust engine here
loads a vocabulary trained by this module instead (DESIGN.md §5 sub. 5 —
same merge-rank BPE algorithm, synthetic corpus). Output is
``artifacts/tokenizer.json``:

  {
    "vocab_size": 4096,
    "specials": {"<pad>": 0, ...},
    "byte_offset": 8,              # byte b  <->  id 8 + b
    "merges": [[a, b], ...]        # merge i creates id 264 + i
  }

Token id space: [0, 8) specials, [8, 264) raw bytes, [264, 264+#merges)
merged tokens, remainder up to vocab_size unused (decoded as empty).
"""

from __future__ import annotations

import collections
import json
import re
from typing import Dict, List, Tuple

SPECIALS = {
    "<pad>": 0,
    "<bos>": 1,
    "<eos>": 2,
    "<unk>": 3,
    "<|system|>": 4,
    "<|user|>": 5,
    "<|assistant|>": 6,
    "<|end|>": 7,
}
BYTE_OFFSET = 8
FIRST_MERGE_ID = BYTE_OFFSET + 256

# GPT-2-style pretokenizer: words keep their leading space. The Rust
# tokenizer (rust/src/tokenizer/) mirrors this split exactly; re.ASCII so
# \s means ASCII whitespace in both implementations.
_PRETOKEN_RE = re.compile(r" ?[A-Za-z]+| ?[0-9]+| ?[^\sA-Za-z0-9]+|\s+", re.ASCII)


# An original corpus: enough distributional structure for BPE to learn
# word-level merges. Repetition below weights common constructions.
_BASE_CORPUS = """
The web browser is a natural platform for running language models on the
device. A user opens a page and the model loads, compiles, and generates
text locally, with no server in the loop. The engine streams tokens back
to the application as they are produced, and the application updates the
interface. Local inference preserves privacy because the prompt never
leaves the machine. It also reduces latency for short requests and makes
personalization with local data straightforward.

Large language models answer questions, write and explain code, draft
messages, summarize documents, and call tools. Smaller open models in the
one to eight billion parameter range now run on consumer hardware when
quantized to four bits. Group quantization stores a scale for every block
of weights, and the kernel dequantizes each tile right before the matrix
multiply, so the full precision weights are never materialized in memory.

The inference engine keeps a paged key value cache. Each sequence owns a
list of pages, and the attention kernel walks the page table to gather
keys and values for every head. A scheduler batches prefill and decode
requests so the device stays busy while responses stream out token by
token. Structured generation constrains sampling with a grammar so the
output always parses as JSON when the application requires it.

A request arrives as a JSON object with a list of messages. The engine
renders the chat template, tokenizes the prompt, allocates pages, runs
prefill, and then decodes one token per step until a stop condition is
met. The response contains choices, usage counts, and a finish reason.
Temperature, top p, presence and frequency penalties, logit bias, and
seeds control sampling. Streaming responses deliver deltas in chunks.

def add(a, b): return a + b
for i in range(10): print(i)
let x = {"key": "value", "count": 42, "items": [1, 2, 3], "ok": true};
SELECT name, count FROM models WHERE params < 8000000000 ORDER BY name;
{"model": "llama", "temperature": 0.7, "max_tokens": 128, "stream": true}
fn main() { println!("hello, world"); }
<html><body><p>hello</p></body></html>
http://example.com/models?size=small&format=q4

zero one two three four five six seven eight nine ten eleven twelve
alpha beta gamma delta epsilon zeta eta theta iota kappa lambda mu nu
red orange yellow green blue indigo violet black white gray brown pink
monday tuesday wednesday thursday friday saturday sunday january june
run ran running walk walked walking think thought thinking say said
good better best bad worse worst big bigger biggest small smaller
I you he she it we they me him her us them my your his its our their
a an the and or but if then else when while for to of in on at by with
is are was were be been being have has had do does did will would can
could should may might must not no yes this that these those there here
what which who whom whose why how all any both each few more most other
some such only own same so than too very just now also after before
"""


def default_corpus() -> str:
    # Weight the prose 3x so natural-language merges dominate, then add
    # the structured tails once.
    return _BASE_CORPUS * 3


def pretokenize(text: str) -> List[str]:
    return _PRETOKEN_RE.findall(text)


def train_bpe(corpus: str, vocab_size: int) -> List[Tuple[int, int]]:
    """Train merge list on the corpus. Returns merges in rank order."""
    word_counts = collections.Counter(pretokenize(corpus))
    # Each unique word as a list of symbol ids (bytes shifted by offset).
    words: List[List[int]] = []
    counts: List[int] = []
    for w, c in word_counts.items():
        words.append([BYTE_OFFSET + b for b in w.encode("utf-8")])
        counts.append(c)

    merges: List[Tuple[int, int]] = []
    next_id = FIRST_MERGE_ID
    max_merges = vocab_size - FIRST_MERGE_ID

    while len(merges) < max_merges:
        pair_counts: collections.Counter = collections.Counter()
        for seq, c in zip(words, counts):
            for a, b in zip(seq, seq[1:]):
                pair_counts[(a, b)] += c
        if not pair_counts:
            break
        (a, b), freq = pair_counts.most_common(1)[0]
        if freq < 2:
            break
        merges.append((a, b))
        for i, seq in enumerate(words):
            if len(seq) < 2:
                continue
            out = []
            j = 0
            while j < len(seq):
                if j + 1 < len(seq) and seq[j] == a and seq[j + 1] == b:
                    out.append(next_id)
                    j += 2
                else:
                    out.append(seq[j])
                    j += 1
            words[i] = out
        next_id += 1
    return merges


def token_bytes(merges: List[Tuple[int, int]]) -> List[bytes]:
    """Materialize the byte string of every id (empty for specials/unused)."""
    table: List[bytes] = [b""] * BYTE_OFFSET
    table += [bytes([i]) for i in range(256)]
    for a, b in merges:
        table.append(table[a] + table[b])
    return table


def build_tokenizer(vocab_size: int = 4096, corpus: str | None = None) -> Dict:
    merges = train_bpe(corpus or default_corpus(), vocab_size)
    return {
        "vocab_size": vocab_size,
        "specials": SPECIALS,
        "byte_offset": BYTE_OFFSET,
        "merges": [list(m) for m in merges],
    }


def encode(tok: Dict, text: str) -> List[int]:
    """Reference encoder (mirrors the Rust implementation) for tests."""
    ranks = {tuple(m): FIRST_MERGE_ID + i for i, m in enumerate(tok["merges"])}
    ids: List[int] = []
    for word in pretokenize(text):
        seq = [BYTE_OFFSET + b for b in word.encode("utf-8")]
        while len(seq) >= 2:
            best = None
            for j, pair in enumerate(zip(seq, seq[1:])):
                r = ranks.get(pair)
                if r is not None and (best is None or r < best[0]):
                    best = (r, j)
            if best is None:
                break
            r, j = best
            seq = seq[:j] + [r] + seq[j + 2:]
        ids.extend(seq)
    return ids


def decode(tok: Dict, ids: List[int]) -> str:
    table = token_bytes([tuple(m) for m in tok["merges"]])
    out = b""
    for i in ids:
        if 0 <= i < len(table):
            out += table[i]
    return out.decode("utf-8", errors="replace")


if __name__ == "__main__":
    import sys

    tok = build_tokenizer()
    path = sys.argv[1] if len(sys.argv) > 1 else "tokenizer.json"
    with open(path, "w") as f:
        json.dump(tok, f)
    ids = encode(tok, "The browser runs the model locally.")
    print(f"{len(tok['merges'])} merges; roundtrip: {decode(tok, ids)!r}")
