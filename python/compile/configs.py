"""Model zoo for the WebLLM reproduction.

Table 1 of the paper evaluates Llama-3.1-8B and Phi-3.5-mini (3.8B), both
4-bit quantized. 8B-class models are not feasible on this CPU-PJRT
testbed, so we ship architecture-preserving scaled stand-ins (DESIGN.md §5):

  * ``llama-web-80m`` — Llama-family shape: GQA (12 q heads / 4 kv heads),
    SwiGLU FFN at ~2.7x, deeper stack. Stand-in for Llama-3.1-8B.
  * ``phi-web-38m``   — Phi-family shape: MHA (kv heads == q heads), 4x
    FFN, shallower/wider-per-param stack. Stand-in for Phi-3.5-mini.
  * ``tiny-2m``       — test-only config so pytest / cargo test stay fast.

The *size contrast* (80M vs 38M ≈ 2.1x, paper: 8B vs 3.8B ≈ 2.1x) and the
architectural contrasts are preserved; absolute tok/s is not a target.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict
from typing import List


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    ffn_dim: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # Paged KV cache geometry. Pool sized for max_decode_batch sequences
    # at max_seq_len plus slack; smaller pools also mean less buffer
    # traffic per step on the CPU substrate (EXPERIMENTS.md §Perf).
    page_size: int = 16
    num_pages: int = 136          # 8 seqs x 16 pages + garbage + slack
    max_seq_len: int = 256
    # Static-shape menu compiled ahead of time (TVM/WebGPU-style discipline).
    prefill_chunks: List[int] = field(default_factory=lambda: [16, 32, 64, 128])
    decode_batches: List[int] = field(default_factory=lambda: [1, 2, 4, 8])

    @property
    def max_pages_per_seq(self) -> int:
        return self.max_seq_len // self.page_size

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        d, f, v = self.d_model, self.ffn_dim, self.vocab_size
        per_layer = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + 3 * d * f
        per_layer += 2 * d  # norms
        return v * d + self.n_layers * per_layer + d + d * v

    def to_dict(self) -> dict:
        d = asdict(self)
        d["max_pages_per_seq"] = self.max_pages_per_seq
        d["param_count"] = self.param_count()
        return d


LLAMA_WEB = ModelConfig(
    name="llama-web-80m",
    vocab_size=4096,
    d_model=768,
    n_layers=12,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    ffn_dim=2048,
    rope_theta=500000.0,  # Llama-3 family value
)

PHI_WEB = ModelConfig(
    name="phi-web-38m",
    vocab_size=4096,
    d_model=512,
    n_layers=8,
    n_heads=8,
    n_kv_heads=8,   # MHA, like Phi-3.5-mini's 32/32 layout at scale
    head_dim=64,
    ffn_dim=2048,   # 4x ratio
    rope_theta=10000.0,
)

TINY = ModelConfig(
    name="tiny-2m",
    vocab_size=4096,
    d_model=128,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    ffn_dim=256,
    page_size=8,
    num_pages=64,
    max_seq_len=128,
    prefill_chunks=[16, 32, 64, 128],
    decode_batches=[1, 2, 4],
)

ALL_CONFIGS = {c.name: c for c in (LLAMA_WEB, PHI_WEB, TINY)}


def get_config(name: str) -> ModelConfig:
    return ALL_CONFIGS[name]
