"""PagedAttention decode kernel (Pallas).

The WebGPU PagedAttention kernel WebLLM ships (via MLC-LLM's TVM codegen)
assigns one workgroup per (sequence, kv-head); the workgroup walks the
sequence's block table, streams each KV page from storage buffers into
workgroup shared memory, and keeps a running online-softmax accumulator.

The Pallas translation: grid = (B, KVH); per program, the block table row
lives in VMEM, pages are gathered from the HBM-resident pool with dynamic
`pl.load`s inside a `fori_loop`, and the online-softmax state (m, l, acc)
stays in registers/VMEM. GQA query groups ride along as a [group, Dh]
block so one pass over the pages serves all query heads sharing the kv
head — exactly the amortization the WebGPU kernel does with its
q-head-per-subgroup layout.

Shapes (shared with ref.py, model.py, and the Rust runtime):
  q:            f32[B, H, Dh]
  k_pages:      f32[P, page, KVH, Dh]
  v_pages:      f32[P, page, KVH, Dh]
  block_tables: i32[B, max_pages]
  seq_lens:     i32[B]    (0 => padding slot; output zeroed)
  out:          f32[B, H, Dh]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _paged_attention_gather_kernel(
    bt_ref, len_ref, q_ref, k_pages_ref, v_pages_ref, o_ref, *, scale: float, page: int
):
    """CPU-lowering schedule: one gather of every sequence's pages, then a
    dense masked softmax, fully vectorized over (B, KVH, group) in a single
    program. The serialized per-page online-softmax loop of the TPU
    schedule costs ~10x on XLA:CPU; emitting a backend-specialized kernel
    is exactly what the paper's MLC/TVM stack does per target."""
    q = q_ref[...] * scale  # [B, KVH, group, Dh]
    seq_lens = len_ref[...]  # [B]
    bt = bt_ref[...]  # [B, max_pages]
    b, kvh, group, dh = q.shape
    max_pages = bt.shape[1]

    k = k_pages_ref[...]  # [P, page, KVH, Dh]
    v = v_pages_ref[...]
    l_tot = max_pages * page
    # [B, max_pages, page, KVH, Dh] -> [B, L, KVH, Dh]
    k_seq = k[bt].reshape(b, l_tot, kvh, dh)
    v_seq = v[bt].reshape(b, l_tot, kvh, dh)

    # [B, KVH, group, L]
    s = jnp.einsum("bhgd,blhd->bhgl", q, k_seq, preferred_element_type=jnp.float32)
    pos = jax.lax.iota(jnp.int32, l_tot)
    valid = pos[None, :] < seq_lens[:, None]  # [B, L]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v_seq, preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)
    out = jnp.where((seq_lens > 0)[:, None, None, None], out, 0.0)
    o_ref[...] = out


def _paged_attention_kernel(
    bt_ref, len_ref, q_ref, k_pages_ref, v_pages_ref, o_ref, *, scale: float, page: int
):
    group, dh = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[...][0, 0] * scale  # [group, Dh]
    seq_len = len_ref[0]
    max_pages = bt_ref.shape[1]

    def body(i, carry):
        m, l, acc = carry
        page_idx = bt_ref[0, i]
        # [page, Dh] for this program's kv head (head axis already blocked).
        k = pl.load(
            k_pages_ref, (pl.dslice(page_idx, 1), slice(None), slice(None), slice(None))
        )[0, :, 0, :]
        v = pl.load(
            v_pages_ref, (pl.dslice(page_idx, 1), slice(None), slice(None), slice(None))
        )[0, :, 0, :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [group, page]
        pos = i * page + jax.lax.iota(jnp.int32, page)
        s = jnp.where((pos < seq_len)[None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((group, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((group, 1), jnp.float32)
    acc0 = jnp.zeros((group, dh), jnp.float32)
    # Only walk pages that can hold valid tokens.
    n_pages = jnp.minimum((seq_len + page - 1) // page, max_pages)
    _, l, acc = jax.lax.fori_loop(0, n_pages, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)
    out = jnp.where(seq_len > 0, out, 0.0)
    o_ref[...] = out[None, None, :, :]


def paged_attention_decode(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,
    seq_lens: jnp.ndarray,
    schedule: str = "paged_loop",
) -> jnp.ndarray:
    """Decode attention over the paged KV pool. See module docstring.

    schedule:
      * "paged_loop" — the TPU-shaped schedule (per-page online softmax);
        correctness-checked against ref.py, structure documented in
        DESIGN.md §7. Default for tests.
      * "gather" — backend-specialized schedule used when lowering the
        CPU-PJRT artifacts (aot.py); identical math, no serial page loop.
    """
    b, h, dh = q.shape
    p_total, page, kvh, dh2 = k_pages.shape
    assert dh == dh2 and h % kvh == 0
    group = h // kvh
    max_pages = block_tables.shape[1]
    scale = 1.0 / float(dh) ** 0.5

    # [B, KVH, group, Dh]: kv-head-major so each program's q block is a
    # contiguous [group, Dh] tile.
    qg = q.reshape(b, kvh, group, dh)

    if schedule == "paged_loop":
        out = pl.pallas_call(
            functools.partial(_paged_attention_kernel, scale=scale, page=page),
            grid=(b, kvh),
            in_specs=[
                pl.BlockSpec((1, max_pages), lambda bb, hh: (bb, 0)),
                pl.BlockSpec((1,), lambda bb, hh: (bb,)),
                pl.BlockSpec((1, 1, group, dh), lambda bb, hh: (bb, hh, 0, 0)),
                # Page pools: blocked on the kv-head axis only; the page axis
                # is gathered dynamically inside the kernel.
                pl.BlockSpec((p_total, page, 1, dh), lambda bb, hh: (0, 0, hh, 0)),
                pl.BlockSpec((p_total, page, 1, dh), lambda bb, hh: (0, 0, hh, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, dh), lambda bb, hh: (bb, hh, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, kvh, group, dh), jnp.float32),
            interpret=True,
        )(block_tables, seq_lens, qg, k_pages, v_pages)
    elif schedule == "gather":
        # Single program, whole arrays: the XLA:CPU-specialized schedule.
        out = pl.pallas_call(
            functools.partial(_paged_attention_gather_kernel, scale=scale, page=page),
            out_shape=jax.ShapeDtypeStruct((b, kvh, group, dh), jnp.float32),
            interpret=True,
        )(block_tables, seq_lens, qg, k_pages, v_pages)
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    return out.reshape(b, h, dh)
