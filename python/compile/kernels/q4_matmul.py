"""Fused 4-bit dequant + GEMM Pallas kernel.

This is the WebLLM-critical kernel: MLC-LLM's WebGPU codegen fuses the
group-wise int4 dequantization into the GEMM so the fp weights are never
materialized in (browser) memory — each workgroup unpacks the nibbles for
its tile right before the multiply. We express the same schedule for the
TPU model: packed u32 words stream HBM->VMEM tile-by-tile via BlockSpec,
the nibble unpack happens in registers, and the product targets the MXU
(jnp.dot with f32 accumulation).

Layout (shared with ref.py and the Rust runtime):
  x:        f32[M, K]
  w_packed: u32[K // 8, N]   — 8 nibbles per word along K
  w_scales: f32[K // G, N]   — G = GROUP_SIZE = 64
  out:      f32[M, N]

Grid: one program per N-tile (M is small on the decode path: the batch).
K is kept whole per tile: for the model sizes this repo ships, a full-K
tile is (K/8)*BN*4 + (K/G)*BN*4 + M*K*4 bytes of VMEM — see DESIGN.md §7
for the budget table. interpret=True is mandatory on CPU PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import GROUP_SIZE, PACK


def _q4_matmul_kernel(x_ref, wp_ref, ws_ref, o_ref):
    x = x_ref[...]  # [M, K]
    wp = wp_ref[...]  # [K//8, BN] u32
    ws = ws_ref[...]  # [K//G, BN] f32

    k8, bn = wp.shape
    shifts = jnp.arange(PACK, dtype=jnp.uint32) * 4
    # Unpack in-register: [K//8, 8, BN] -> [K, BN]; nibble i of word k8 is
    # row k8*8+i. (q - 8) centers the 4-bit code.
    nib = (wp[:, None, :] >> shifts[None, :, None]) & jnp.uint32(0xF)
    q = nib.reshape(k8 * PACK, bn).astype(jnp.float32) - 8.0
    scales = jnp.repeat(ws, GROUP_SIZE, axis=0)  # [K, BN]
    w = q * scales
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


def q4_matmul(
    x: jnp.ndarray,
    w_packed: jnp.ndarray,
    w_scales: jnp.ndarray,
    schedule: str = "tiled",
) -> jnp.ndarray:
    """x @ dequant(w_packed, w_scales) via the fused Pallas kernel.

    schedule:
      * "tiled"  — N-tiled grid, the TPU/WebGPU-shaped schedule (each
        program's tile sized for VMEM/workgroup memory). Default, used by
        the correctness tests.
      * "single" — one program over the whole matrix: the XLA:CPU
        specialization (interpret-mode grids serialize, so per-tile loop
        overhead dominates at decode's M=1; measured up to 13x on the
        lm_head GEMM — EXPERIMENTS.md §Perf). aot.py lowers artifacts
        with this, the same per-backend kernel specialization MLC/TVM
        performs for WebGPU vs Metal.
    """
    m, k = x.shape
    k8, n = w_packed.shape
    assert k8 * PACK == k, f"packed K mismatch: {k8}*{PACK} != {k}"
    assert w_scales.shape == (k // GROUP_SIZE, n)

    if schedule == "single":
        return pl.pallas_call(
            _q4_matmul_kernel,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(x, w_packed, w_scales)

    bn = _pick_bn(n)
    grid = (n // bn,)
    return pl.pallas_call(
        _q4_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k8, bn), lambda j: (0, j)),
            pl.BlockSpec((k // GROUP_SIZE, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_packed, w_scales)


def _pick_bn(n: int) -> int:
    """Largest MXU-friendly N-tile that divides N (<= 512)."""
    for bn in (512, 256, 128, 64, 32, 16, 8):
        if n % bn == 0:
            return bn
    return n
