"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: deliberately simple, no tiling, no
fused dequantization, no paging tricks. pytest (python/tests/) asserts the
Pallas kernels match these under `interpret=True`, and the L2 model has a
full-attention reference (`ref_forward` in model.py) built from the same
primitives.
"""

from __future__ import annotations

import jax.numpy as jnp

# Group size for 4-bit group-wise quantization (along the reduction dim K).
GROUP_SIZE = 64
# Nibbles packed per u32 word (along K).
PACK = 8


def dequant_q4(w_packed: jnp.ndarray, w_scales: jnp.ndarray) -> jnp.ndarray:
    """Unpack group-quantized 4-bit weights to f32.

    w_packed: u32[K // 8, N]   — 8 nibbles per word along K.
    w_scales: f32[K // G, N]   — one scale per (group, output).
    returns:  f32[K, N] with w = (q - 8) * scale.
    """
    k8, n = w_packed.shape
    shifts = jnp.arange(PACK, dtype=jnp.uint32) * 4
    # [K//8, 8, N] — nibble `i` of word `k8` is element k8*8+i along K.
    nibbles = (w_packed[:, None, :] >> shifts[None, :, None]) & jnp.uint32(0xF)
    q = nibbles.reshape(k8 * PACK, n).astype(jnp.float32) - 8.0
    scales = jnp.repeat(w_scales, GROUP_SIZE, axis=0)
    return q * scales


def q4_matmul(x: jnp.ndarray, w_packed: jnp.ndarray, w_scales: jnp.ndarray) -> jnp.ndarray:
    """Reference for the fused dequant-GEMM kernel: x @ dequant(w).

    x: f32[M, K]; returns f32[M, N].
    """
    return x @ dequant_q4(w_packed, w_scales)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Reference RMSNorm over the last axis. x: f32[T, D], w: f32[D]."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax_rsqrt(ms + eps) * w


def jax_rsqrt(x: jnp.ndarray) -> jnp.ndarray:
    return 1.0 / jnp.sqrt(x)


def prefill_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    seq_len: int,
) -> jnp.ndarray:
    """Reference causal attention over one (padded) prefill chunk.

    q: f32[T, H, Dh]; k, v: f32[T, KVH, Dh] (GQA: H % KVH == 0).
    Positions >= seq_len are padding; their keys are masked out and their
    outputs are unconstrained garbage (the model discards them).
    returns f32[T, H, Dh].
    """
    t, h, dh = q.shape
    kvh = k.shape[1]
    group = h // kvh
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    kq = jnp.repeat(k, group, axis=1)  # [T, H, Dh]
    vq = jnp.repeat(v, group, axis=1)
    # [H, T, T]
    s = jnp.einsum("qhd,khd->hqk", q, kq) * scale
    pos = jnp.arange(t)
    causal = pos[None, :] <= pos[:, None]  # key j attends-to query i iff j <= i
    valid = pos[None, :] < seq_len
    mask = (causal & valid)[None, :, :]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", p, vq)


def paged_attention_decode(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    block_tables: jnp.ndarray,
    seq_lens: jnp.ndarray,
) -> jnp.ndarray:
    """Reference decode attention over a paged KV pool.

    q:            f32[B, H, Dh]     — one query token per sequence.
    k_pages:      f32[P, page, KVH, Dh] — global page pool.
    v_pages:      f32[P, page, KVH, Dh]
    block_tables: i32[B, max_pages] — page ids per sequence, in order.
    seq_lens:     i32[B]            — tokens valid per sequence (incl. current).
    returns       f32[B, H, Dh].

    Gathers each sequence's pages into a contiguous [max_pages*page] KV run,
    masks beyond seq_len, and does dense softmax attention. Sequences with
    seq_len == 0 (padding slots) produce zeros.
    """
    b, h, dh = q.shape
    p_total, page, kvh, _ = k_pages.shape
    group = h // kvh
    max_pages = block_tables.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    # [B, max_pages, page, KVH, Dh] -> [B, L, KVH, Dh], L = max_pages * page
    k_seq = k_pages[block_tables].reshape(b, max_pages * page, kvh, dh)
    v_seq = v_pages[block_tables].reshape(b, max_pages * page, kvh, dh)
    k_seq = jnp.repeat(k_seq, group, axis=2)  # [B, L, H, Dh]
    v_seq = jnp.repeat(v_seq, group, axis=2)

    s = jnp.einsum("bhd,blhd->bhl", q, k_seq) * scale
    pos = jnp.arange(max_pages * page)
    valid = pos[None, :] < seq_lens[:, None]  # [B, L]
    s = jnp.where(valid[:, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhl,blhd->bhd", p, v_seq) / jnp.maximum(denom, 1e-30)
    # Zero out padding sequences entirely (denom there is degenerate).
    return jnp.where((seq_lens > 0)[:, None, None], out, 0.0)
