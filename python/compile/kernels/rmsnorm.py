"""Fused RMSNorm Pallas kernel.

WebLLM/MLC fuse normalization with the adjacent elementwise ops into one
WebGPU dispatch; here the whole normalize-and-scale is one Pallas program
per row-tile so the row statistics never leave VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]  # [BT, D]
    w = w_ref[...]  # [1, D]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * w


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm over the last axis. x: f32[T, D], w: f32[D] -> f32[T, D]."""
    t, d = x.shape
    bt = _pick_bt(t)
    import functools

    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, w.reshape(1, d))


def _pick_bt(t: int) -> int:
    for bt in (64, 32, 16, 8, 4, 2, 1):
        if t % bt == 0:
            return bt
    return 1
