"""Causal prefill attention Pallas kernels.

Two schedules:

* ``prefill_attention`` — flash-style attention over one self-contained
  chunk (the whole prompt lives in the chunk). WebLLM compiles a
  FlashAttention-like WebGPU kernel per model; the
  threadblock-per-(head, query-tile) decomposition maps here to a Pallas
  grid over heads with the whole chunk's scores kept in VMEM (chunks are
  <= 128 tokens, so the [T, T] score tile fits comfortably; see
  DESIGN.md §7). Kept as the oracle for ``ref.py`` consistency tests.

* ``chunk_prefill_attention`` — *positioned* chunk attention for the
  scheduler's chunked prefill (Sarathi-style prefill/decode
  interleaving): the chunk's queries sit at absolute positions
  ``start_pos + i`` and attend over the **paged pool** through the
  sequence's block table, so keys written by earlier chunks (or reused
  verbatim from a prefix-cache hit) participate without recompute. The
  page gather + dense masked softmax mirrors the decode kernel's
  "gather" schedule (paged_attention.py), which is the XLA:CPU-
  specialized lowering the artifacts use.

GQA is expressed in the index maps / reshapes: query head h reads kv
head h // (H / KVH), so no repeated K/V is ever materialized.

Padding: chunk rows >= n (and pool positions >= start_pos + row + 1) are
masked out of the keys; padding rows' outputs are well-defined but the
model discards them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prefill_attention_kernel(seq_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[...][:, 0, :]  # [T, Dh]
    k = k_ref[...][:, 0, :]  # [T, Dh]
    v = v_ref[...][:, 0, :]
    seq_len = seq_ref[0]

    t = q.shape[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [T, T]
    pos = jax.lax.iota(jnp.int32, t)
    causal = pos[None, :] <= pos[:, None]
    valid = pos[None, :] < seq_len
    s = jnp.where(causal & valid, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)[:, None, :]


def prefill_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    seq_len: jnp.ndarray,
) -> jnp.ndarray:
    """Causal attention over one padded chunk.

    q: f32[T, H, Dh]; k, v: f32[T, KVH, Dh]; seq_len: i32[] or i32[1].
    returns f32[T, H, Dh].
    """
    t, h, dh = q.shape
    kvh = k.shape[1]
    assert h % kvh == 0
    group = h // kvh
    scale = 1.0 / float(dh) ** 0.5
    seq_len = jnp.asarray(seq_len, jnp.int32).reshape(1)

    return pl.pallas_call(
        functools.partial(_prefill_attention_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda hh: (0,)),
            pl.BlockSpec((t, 1, dh), lambda hh: (0, hh, 0)),
            pl.BlockSpec((t, 1, dh), lambda hh: (0, hh // group, 0)),
            pl.BlockSpec((t, 1, dh), lambda hh: (0, hh // group, 0)),
        ],
        out_specs=pl.BlockSpec((t, 1, dh), lambda hh: (0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, dh), jnp.float32),
        interpret=True,
    )(seq_len, q, k, v)


def _chunk_prefill_kernel(
    start_ref, n_ref, bt_ref, q_ref, k_pages_ref, v_pages_ref, o_ref, *, scale: float, page: int
):
    """Single program, whole arrays: gather the sequence's pages, then a
    dense causally-masked softmax at absolute positions (the XLA:CPU
    schedule; see paged_attention._paged_attention_gather_kernel)."""
    q = q_ref[...] * scale  # [T, KVH, group, Dh]
    start = start_ref[0]
    n = n_ref[0]
    bt = bt_ref[...]  # [max_pages]
    t, kvh, group, dh = q.shape
    max_pages = bt.shape[0]
    l_tot = max_pages * page

    k = k_pages_ref[...]  # [P, page, KVH, Dh]
    v = v_pages_ref[...]
    # [max_pages, page, KVH, Dh] -> [L, KVH, Dh]
    k_seq = k[bt].reshape(l_tot, kvh, dh)
    v_seq = v[bt].reshape(l_tot, kvh, dh)

    # [T, KVH, group, L]
    s = jnp.einsum("thgd,lhd->thgl", q, k_seq, preferred_element_type=jnp.float32)
    qpos = start + jax.lax.iota(jnp.int32, t)  # absolute query positions
    kpos = jax.lax.iota(jnp.int32, l_tot)
    # Causal at absolute positions; padding rows (i >= n) clamp to the
    # last valid row's horizon so their softmax stays well-defined.
    horizon = jnp.minimum(qpos, start + n - 1)
    mask = kpos[None, :] <= horizon[:, None]  # [T, L]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("thgl,lhd->thgd", p, v_seq, preferred_element_type=jnp.float32)
    o_ref[...] = out / jnp.maximum(l, 1e-30)


def chunk_prefill_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    block_table: jnp.ndarray,
    start_pos: jnp.ndarray,
    n: jnp.ndarray,
) -> jnp.ndarray:
    """Positioned chunk attention over the paged pool. See module docstring.

    q: f32[T, H, Dh] (chunk queries, rows >= n are padding);
    k_pages, v_pages: f32[P, page, KVH, Dh] (chunk K/V already written);
    block_table: i32[max_pages]; start_pos, n: i32[] or i32[1].
    returns f32[T, H, Dh].
    """
    t, h, dh = q.shape
    p_total, page, kvh, dh2 = k_pages.shape
    assert dh == dh2 and h % kvh == 0
    group = h // kvh
    scale = 1.0 / float(dh) ** 0.5
    start_pos = jnp.asarray(start_pos, jnp.int32).reshape(1)
    n = jnp.asarray(n, jnp.int32).reshape(1)

    # [T, KVH, group, Dh]: kv-head-major so GQA groups share one gather.
    qg = q.reshape(t, kvh, group, dh)
    out = pl.pallas_call(
        functools.partial(_chunk_prefill_kernel, scale=scale, page=page),
        out_shape=jax.ShapeDtypeStruct((t, kvh, group, dh), jnp.float32),
        interpret=True,
    )(start_pos, n, block_table, qg, k_pages, v_pages)
    return out.reshape(t, h, dh)
