"""Causal prefill attention Pallas kernel (flash-style, one chunk).

WebLLM compiles a FlashAttention-like WebGPU kernel per model; the
threadblock-per-(head, query-tile) decomposition maps here to a Pallas
grid over heads with the whole chunk's scores kept in VMEM (chunks are
<= 128 tokens, so the [T, T] score tile fits comfortably; see DESIGN.md §7).

GQA is expressed in the BlockSpec index maps: query head h reads kv head
h // (H / KVH), so no repeated K/V is ever materialized.

Padding: positions >= seq_len are masked out of the keys; their output
rows are well-defined (softmax over the valid prefix) but the model
discards them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prefill_attention_kernel(seq_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[...][:, 0, :]  # [T, Dh]
    k = k_ref[...][:, 0, :]  # [T, Dh]
    v = v_ref[...][:, 0, :]
    seq_len = seq_ref[0]

    t = q.shape[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [T, T]
    pos = jax.lax.iota(jnp.int32, t)
    causal = pos[None, :] <= pos[:, None]
    valid = pos[None, :] < seq_len
    s = jnp.where(causal & valid, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)[:, None, :]


def prefill_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    seq_len: jnp.ndarray,
) -> jnp.ndarray:
    """Causal attention over one padded chunk.

    q: f32[T, H, Dh]; k, v: f32[T, KVH, Dh]; seq_len: i32[] or i32[1].
    returns f32[T, H, Dh].
    """
    t, h, dh = q.shape
    kvh = k.shape[1]
    assert h % kvh == 0
    group = h // kvh
    scale = 1.0 / float(dh) ** 0.5
    seq_len = jnp.asarray(seq_len, jnp.int32).reshape(1)

    return pl.pallas_call(
        functools.partial(_prefill_attention_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda hh: (0,)),
            pl.BlockSpec((t, 1, dh), lambda hh: (0, hh, 0)),
            pl.BlockSpec((t, 1, dh), lambda hh: (0, hh // group, 0)),
            pl.BlockSpec((t, 1, dh), lambda hh: (0, hh // group, 0)),
        ],
        out_specs=pl.BlockSpec((t, 1, dh), lambda hh: (0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, dh), jnp.float32),
        interpret=True,
    )(seq_len, q, k, v)
