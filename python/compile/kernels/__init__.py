"""Layer-1 Pallas kernels (the AOT "WebGPU kernel" analog) + jnp oracles."""

from .paged_attention import paged_attention_decode
from .prefill_attention import chunk_prefill_attention, prefill_attention
from .q4_matmul import q4_matmul
from .rmsnorm import rmsnorm

__all__ = [
    "chunk_prefill_attention",
    "paged_attention_decode",
    "prefill_attention",
    "q4_matmul",
    "rmsnorm",
]
