"""AOT artifact builder — the "MLC-LLM compile" analog (build-time only).

Produces everything the Rust runtime needs, so Python is never on the
request path:

  artifacts/
    manifest.json                      — models, arg schemas, file map
    tokenizer.json                     — byte-level BPE vocab
    <model>/config.json                — ModelConfig dump
    <model>/weights_q4.bin             — packed q4 weights + scales (raw LE)
    <model>/prefill_c<T>.hlo.txt       — one executable per chunk size
    <model>/decode_b<B>.hlo.txt        — one executable per batch size

HLO **text** is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the Rust `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Argument order convention (shared with rust/src/runtime/exec.rs):
  prefill: [ids(T) i32, start_pos(1) i32, n(1) i32, block_table(MP) i32] + weights + [k_pages, v_pages]
  decode:  [ids(B) i32, positions(B) i32, seq_lens(B) i32, block_tables(B,MP) i32] + weights + [k_pages, v_pages]
Outputs (a flat tuple): (logits f32, k_pages, v_pages).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import ALL_CONFIGS, ModelConfig
from .kernels.ref import GROUP_SIZE, PACK
from .tokenizer_gen import build_tokenizer

DTYPES = {"f32": jnp.float32, "u32": jnp.uint32, "i32": jnp.int32}
NP_DTYPES = {"f32": np.float32, "u32": np.uint32, "i32": np.int32}
ALIGN = 64

# Attention schedule for lowered artifacts: the CPU-specialized one
# (DESIGN.md §Hardware-Adaptation — per-backend kernel specialization is
# what MLC/TVM do for WebGPU vs Metal vs CUDA).
ARTIFACT_SCHEDULE = "gather"
# q4 GEMM schedule for CPU artifacts (see kernels/q4_matmul.py): "single"
# collapses the N-tile grid, which interpret-mode serializes.
ARTIFACT_Q4_SCHEDULE = "single"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _struct(shape, ty: str) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), DTYPES[ty])


def _spec_dicts(specs) -> List[Dict]:
    return [{"name": n, "shape": list(s), "dtype": t} for n, s, t in specs]


def build_weights(cfg: ModelConfig, out_dir: str, seed: int) -> List[Dict]:
    """Write weights_q4.bin; returns manifest entries with offsets."""
    weights = M.init_weights(cfg, seed=seed)
    entries: List[Dict] = []
    path = os.path.join(out_dir, cfg.name, "weights_q4.bin")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    off = 0
    with open(path, "wb") as f:
        for name, shape, ty in M.weight_specs(cfg):
            arr = np.ascontiguousarray(weights[name].astype(NP_DTYPES[ty], copy=False))
            pad = (-off) % ALIGN
            f.write(b"\0" * pad)
            off += pad
            raw = arr.tobytes()
            entries.append(
                {
                    "name": name,
                    "shape": list(shape),
                    "dtype": ty,
                    "offset": off,
                    "nbytes": len(raw),
                }
            )
            f.write(raw)
            off += len(raw)
    return entries


def lower_prefill(cfg: ModelConfig, chunk: int) -> str:
    wspecs = M.weight_specs(cfg)
    cshape = M.cache_specs(cfg)[0][1]

    def fn(ids, start_pos, n, block_table, *flat):
        w = {name: a for (name, _, _), a in zip(wspecs, flat[: len(wspecs)])}
        k_pages, v_pages = flat[len(wspecs):]
        return M.prefill(
            cfg, ids, start_pos[0], n[0], block_table, w, k_pages, v_pages,
            q4_schedule=ARTIFACT_Q4_SCHEDULE,
        )

    args = [
        _struct((chunk,), "i32"),
        _struct((1,), "i32"),
        _struct((1,), "i32"),
        _struct((cfg.max_pages_per_seq,), "i32"),
        *[_struct(s, t) for _, s, t in wspecs],
        _struct(cshape, "f32"),
        _struct(cshape, "f32"),
    ]
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_decode(cfg: ModelConfig, batch: int) -> str:
    wspecs = M.weight_specs(cfg)
    cshape = M.cache_specs(cfg)[0][1]

    def fn(ids, positions, seq_lens, block_tables, *flat):
        w = {n: a for (n, _, _), a in zip(wspecs, flat[: len(wspecs)])}
        k_pages, v_pages = flat[len(wspecs):]
        return M.decode(
            cfg, ids, positions, seq_lens, block_tables, w, k_pages, v_pages,
            attention_schedule=ARTIFACT_SCHEDULE,
            q4_schedule=ARTIFACT_Q4_SCHEDULE,
            # Per-batch layer-loop specialization (EXPERIMENTS.md §Perf):
            # unrolled layers avoid XLA:CPU scan-carry copies at bs 1-2.
            layer_mode="unroll" if batch <= 2 else "scan",
        )

    args = [
        _struct((batch,), "i32"),
        _struct((batch,), "i32"),
        _struct((batch,), "i32"),
        _struct((batch, cfg.max_pages_per_seq), "i32"),
        *[_struct(s, t) for _, s, t in wspecs],
        _struct(cshape, "f32"),
        _struct(cshape, "f32"),
    ]
    # Donate the KV pools on the unrolled (small-batch) artifacts:
    # input_output_alias survives the HLO text round-trip, so PJRT updates
    # the pools in place instead of materializing fresh copies — measured
    # -15%/-40% per step at b=1 (EXPERIMENTS.md §Perf). Under lax.scan the
    # aliasing measurably *hurts* (forces copies at loop boundaries on
    # XLA:CPU 0.5.1), so scan-mode artifacts stay undonated. The Rust
    # runtime chains output buffers and never touches donated inputs.
    if batch <= 2:
        donate = (len(args) - 2, len(args) - 1)
        return to_hlo_text(jax.jit(fn, donate_argnums=donate).lower(*args))
    return to_hlo_text(jax.jit(fn).lower(*args))


def build_model(cfg: ModelConfig, out_dir: str, seed: int, verbose: bool = True) -> Dict:
    mdir = os.path.join(out_dir, cfg.name)
    os.makedirs(mdir, exist_ok=True)

    t0 = time.time()
    weight_entries = build_weights(cfg, out_dir, seed)
    if verbose:
        print(f"[{cfg.name}] weights ({time.time() - t0:.1f}s)")

    with open(os.path.join(mdir, "config.json"), "w") as f:
        json.dump(cfg.to_dict(), f, indent=2)

    prefill_entries = {}
    for chunk in cfg.prefill_chunks:
        t0 = time.time()
        rel = f"{cfg.name}/prefill_c{chunk}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(lower_prefill(cfg, chunk))
        prefill_entries[str(chunk)] = {
            "path": rel,
            "inputs": _spec_dicts(
                [
                    ("ids", (chunk,), "i32"),
                    ("start_pos", (1,), "i32"),
                    ("n", (1,), "i32"),
                    ("block_table", (cfg.max_pages_per_seq,), "i32"),
                ]
            ),
        }
        if verbose:
            print(f"[{cfg.name}] prefill c{chunk} ({time.time() - t0:.1f}s)")

    decode_entries = {}
    for batch in cfg.decode_batches:
        t0 = time.time()
        rel = f"{cfg.name}/decode_b{batch}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(lower_decode(cfg, batch))
        decode_entries[str(batch)] = {
            "path": rel,
            "inputs": _spec_dicts(
                [
                    ("ids", (batch,), "i32"),
                    ("positions", (batch,), "i32"),
                    ("seq_lens", (batch,), "i32"),
                    ("block_tables", (batch, cfg.max_pages_per_seq), "i32"),
                ]
            ),
        }
        if verbose:
            print(f"[{cfg.name}] decode b{batch} ({time.time() - t0:.1f}s)")

    return {
        "config": cfg.to_dict(),
        "weights_bin": f"{cfg.name}/weights_q4.bin",
        "weights": weight_entries,
        "cache": _spec_dicts(M.cache_specs(cfg)),
        "prefill": prefill_entries,
        "decode": decode_entries,
        # Outputs of every executable, in tuple order.
        "outputs": ["logits", "k_pages", "v_pages"],
    }


def build_kernel_benches(out_dir: str) -> Dict:
    """Micro-bench artifacts for the kernel ablation (DESIGN.md A2):
    the fused dequant-GEMM Pallas kernel vs the unfused dequantize-then-
    matmul graph, at the GEMM shapes of both Table-1 models; plus the two
    paged-attention schedules."""
    import jax.numpy as jnp
    from .kernels import paged_attention_decode, q4_matmul
    from .kernels import ref as kref

    kdir = os.path.join(out_dir, "kernel_bench")
    os.makedirs(kdir, exist_ok=True)
    entries = {}

    # GEMM shapes: (M=batch rows, K, N) drawn from llama-web / phi-web.
    shapes = {
        "llama_qkv": (8, 768, 768),
        "llama_ffn": (8, 768, 2048),
        "llama_head": (1, 768, 4096),
        "phi_ffn": (8, 512, 2048),
    }
    for name, (m, k, n) in shapes.items():
        for variant, fn in (
            ("fused", lambda x, wp, ws: (q4_matmul(x, wp, ws, schedule="single"),)),
            ("fused_tiled", lambda x, wp, ws: (q4_matmul(x, wp, ws, schedule="tiled"),)),
            ("unfused", lambda x, wp, ws: (kref.q4_matmul(x, wp, ws),)),
        ):
            args = [
                jax.ShapeDtypeStruct((m, k), jnp.float32),
                jax.ShapeDtypeStruct((k // 8, n), jnp.uint32),
                jax.ShapeDtypeStruct((k // GROUP_SIZE, n), jnp.float32),
            ]
            rel = f"kernel_bench/q4_{name}_{variant}.hlo.txt"
            with open(os.path.join(out_dir, rel), "w") as f:
                f.write(to_hlo_text(jax.jit(fn).lower(*args)))
            entries[f"q4_{name}_{variant}"] = {
                "path": rel,
                "inputs": _spec_dicts(
                    [
                        ("x", (m, k), "f32"),
                        ("w_packed", (k // 8, n), "u32"),
                        ("w_scales", (k // GROUP_SIZE, n), "f32"),
                    ]
                ),
            }

    # Paged attention schedules at llama-web geometry.
    b, h, kvh, dh, p_total, page, mp = 8, 12, 4, 64, 192, 16, 16
    for sched in ("paged_loop", "gather"):
        def attn(q, kp, vp, bt, sl, _s=sched):
            return (paged_attention_decode(q, kp, vp, bt, sl, schedule=_s),)

        args = [
            jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((p_total, page, kvh, dh), jnp.float32),
            jax.ShapeDtypeStruct((p_total, page, kvh, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, mp), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ]
        rel = f"kernel_bench/paged_attention_{sched}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(to_hlo_text(jax.jit(attn).lower(*args)))
        entries[f"paged_attention_{sched}"] = {
            "path": rel,
            "inputs": _spec_dicts(
                [
                    ("q", (b, h, dh), "f32"),
                    ("k_pages", (p_total, page, kvh, dh), "f32"),
                    ("v_pages", (p_total, page, kvh, dh), "f32"),
                    ("block_tables", (b, mp), "i32"),
                    ("seq_lens", (b,), "i32"),
                ]
            ),
        }
    return entries


def source_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip cleanly."""
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(base)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="all", help="comma-separated names or 'all'")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    fp = source_fingerprint()

    if not args.force and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("fingerprint") == fp:
            print(f"artifacts up to date (fingerprint {fp}); use --force to rebuild")
            return

    names = list(ALL_CONFIGS) if args.models == "all" else args.models.split(",")

    t0 = time.time()
    tok = build_tokenizer()
    with open(os.path.join(out_dir, "tokenizer.json"), "w") as f:
        json.dump(tok, f)
    # Cross-language fixtures: the Rust encoder must reproduce these ids
    # exactly (rust/src/tokenizer/tests.rs::fixtures_match_python).
    from .tokenizer_gen import encode as tok_encode
    fixture_texts = [
        "Hello, world!",
        "The engine streams tokens back to the application.",
        '{"key": [1, 2.5, true], "path": "/v1/chat"}',
        "  leading and   multiple   spaces  ",
        "tabs\tand\nnewlines\r\n",
        "mixed CASE words AND numbers 12345 67x89",
        "na\u00efve caf\u00e9 \u2014 d\u00e9j\u00e0 vu \u2014 \u65e5\u672c\u8a9e\u30c6\u30ad\u30b9\u30c8 \u2014 \U0001f600\U0001f389",
        "a" * 100,
        "punctuation!!! ???, ;;; :: () [] {} <> || && ##",
        "vertical\x0btab and \x0c formfeed",
    ]
    fixtures = [{"text": t, "ids": tok_encode(tok, t)} for t in fixture_texts]
    with open(os.path.join(out_dir, "tokenizer_fixtures.json"), "w") as f:
        json.dump(fixtures, f)
    print(f"tokenizer: {len(tok['merges'])} merges ({time.time() - t0:.1f}s)")

    models = {}
    for name in names:
        models[name] = build_model(ALL_CONFIGS[name], out_dir, args.seed)

    t0 = time.time()
    kernel_bench = build_kernel_benches(out_dir)
    print(f"kernel bench artifacts ({time.time() - t0:.1f}s)")

    manifest = {
        # Bumped to 2 when prefill gained the positioned calling
        # convention [ids, start_pos, n, block_table]; the Rust loader
        # rejects other versions so stale artifacts fail at load, not
        # with an opaque execution error mid-prefill.
        "version": 2,
        "fingerprint": fp,
        "group_size": GROUP_SIZE,
        "pack": PACK,
        "seed": args.seed,
        "tokenizer": "tokenizer.json",
        "attention_schedule": ARTIFACT_SCHEDULE,
        "models": models,
        "kernel_bench": kernel_bench,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
