//! End-to-end integration: API -> engine -> backend -> streaming, both
//! native-mode (direct `MLCEngine`) and the worker/frontend path.
//!
//! Runs unconditionally on the deterministic `ReferenceBackend` (the
//! built-in `tiny-ref` registry) — no artifacts, no skips, every
//! scenario exercised in every CI run. XLA-artifact coverage lives in
//! `test_runtime.rs`, which logs a `SKIP:` marker when artifacts are
//! absent.

use webllm::api::{ChatCompletionRequest, FinishReason, ResponseFormat};
use webllm::coordinator::{EngineConfig, EngineEvent, MLCEngine, ServiceWorkerMLCEngine};
use webllm::json::parse;
use webllm::testutil::prop::Runner;
use webllm::testutil::{ban_reference_eos as ban_eos, ban_reference_invisible as ban_invisible};

const MODEL: &str = "tiny-ref";

fn engine() -> MLCEngine {
    MLCEngine::new(&EngineConfig::reference(&[MODEL])).expect("engine")
}

fn frontend() -> ServiceWorkerMLCEngine {
    ServiceWorkerMLCEngine::create(EngineConfig::reference(&[MODEL])).expect("frontend")
}

fn greedy(prompt: &str, max_tokens: usize) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new(MODEL).user(prompt);
    r.max_tokens = max_tokens;
    r.sampling.temperature = 0.0;
    r
}

/// Drain completion events into (per-request responses, all chunks).
fn drain(
    engine: &mut MLCEngine,
) -> (
    Vec<(u64, webllm::api::ChatCompletionResponse)>,
    Vec<(u64, webllm::api::ChatChunk)>,
) {
    let mut done = Vec::new();
    let mut chunks = Vec::new();
    for ev in engine.poll_events() {
        match ev {
            EngineEvent::Done(rid, resp) => done.push((rid, resp)),
            EngineEvent::Chunk(rid, c) => chunks.push((rid, c)),
            EngineEvent::Error(rid, e) => panic!("request {rid} failed: {e}"),
        }
    }
    (done, chunks)
}

// -- basic completion + usage accounting ------------------------------------

#[test]
fn chat_completion_basic() {
    let mut engine = engine();
    let mut req = ChatCompletionRequest::new(MODEL)
        .system("You are a test model.")
        .user("Say something.");
    req.max_tokens = 8;
    req.sampling.seed = Some(1);
    let resp = engine.chat_completion(req).expect("completion");
    assert!(resp.usage.completion_tokens <= 8);
    assert!(resp.usage.prompt_tokens > 4);
    assert!(matches!(
        resp.choices[0].finish_reason,
        FinishReason::Stop | FinishReason::Length
    ));
    assert!(resp.usage.decode_tokens_per_s >= 0.0);
    assert!(resp.usage.e2e_s > 0.0);
}

#[test]
fn usage_counts_are_exact_when_eos_is_banned() {
    let mut engine = engine();
    let mut req = greedy("count my tokens", 9);
    ban_eos(&mut req);
    let resp = engine.chat_completion(req).unwrap();
    assert_eq!(resp.usage.completion_tokens, 9);
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Length);
}

#[test]
fn max_tokens_one_yields_one_token() {
    let mut engine = engine();
    let mut req = greedy("one token", 1);
    ban_eos(&mut req);
    let resp = engine.chat_completion(req).unwrap();
    assert_eq!(resp.usage.completion_tokens, 1);
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Length);
}

#[test]
fn context_length_caps_generation() {
    let mut engine = engine();
    let mut req = greedy("fill the context", 10_000);
    ban_eos(&mut req);
    let resp = engine.chat_completion(req).unwrap();
    // max_seq_len 128 => max context 127; the engine clamps max_tokens.
    assert_eq!(resp.usage.completion_tokens, 127 - resp.usage.prompt_tokens);
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Length);
}

// -- determinism ------------------------------------------------------------

#[test]
fn seeded_determinism_same_engine() {
    let mut engine = engine();
    let mk = || {
        let mut r = ChatCompletionRequest::new(MODEL).user("determinism test");
        r.max_tokens = 12;
        r.sampling.seed = Some(42);
        r.sampling.temperature = 0.9;
        r
    };
    let a = engine.chat_completion(mk()).unwrap();
    let b = engine.chat_completion(mk()).unwrap();
    assert_eq!(a.text(), b.text(), "same seed must reproduce");
}

#[test]
fn greedy_matches_across_fresh_engines() {
    let mut e1 = engine();
    let mut e2 = engine();
    let mk = || greedy("hello world", 10);
    assert_eq!(
        e1.chat_completion(mk()).unwrap().text(),
        e2.chat_completion(mk()).unwrap().text(),
        "greedy decode must be engine-state independent"
    );
}

#[test]
fn prop_seed_determinism_across_fresh_engines() {
    let prompts = ["alpha", "beta gamma", "hello world", "json please", "determinism"];
    Runner::new("seed_determinism_engines", 6).run(|rng| {
        let seed = rng.u64();
        let prompt = *rng.choose(&prompts);
        let temperature = 0.2 + rng.f64() as f32;
        let mk = || {
            let mut r = ChatCompletionRequest::new(MODEL).user(prompt);
            r.max_tokens = 8;
            r.sampling.seed = Some(seed);
            r.sampling.temperature = temperature;
            r
        };
        let a = engine().chat_completion(mk()).map_err(|e| e.to_string())?;
        let b = engine().chat_completion(mk()).map_err(|e| e.to_string())?;
        if a.text() != b.text() {
            return Err(format!(
                "seed {seed} prompt {prompt:?}: {:?} != {:?}",
                a.text(),
                b.text()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_seed_determinism_native_vs_worker() {
    // The worker/frontend path serializes everything through the wire
    // protocol; byte-identical completions prove the boundary is
    // transparent for any (request, seed).
    let prompts = ["over the wire", "worker parity", "stream of tokens"];
    Runner::new("seed_determinism_worker", 4).run(|rng| {
        let seed = rng.u64();
        let prompt = *rng.choose(&prompts);
        let mk = || {
            let mut r = ChatCompletionRequest::new(MODEL).user(prompt);
            r.max_tokens = 8;
            r.sampling.seed = Some(seed);
            r.sampling.temperature = 0.8;
            r
        };
        let native = engine().chat_completion(mk()).map_err(|e| e.to_string())?;
        let worker = frontend().chat_completion(mk()).map_err(|e| e.to_string())?;
        if native.text() != worker.text() {
            return Err(format!(
                "seed {seed}: native {:?} != worker {:?}",
                native.text(),
                worker.text()
            ));
        }
        Ok(())
    });
}

// -- continuous batching ----------------------------------------------------

#[test]
fn concurrent_requests_continuous_batching() {
    let mut engine = engine();
    let mut ids = Vec::new();
    for i in 0..5 {
        let mut r = greedy(&format!("request {i}"), 6);
        ban_eos(&mut r);
        ids.push(engine.submit(r).unwrap());
    }
    engine.run_to_completion().unwrap();
    let (done, _) = drain(&mut engine);
    assert_eq!(done.len(), 5);
    for (_, resp) in &done {
        assert_eq!(resp.usage.completion_tokens, 6);
    }
    // Batching actually happened: some decode steps covered >1 sequence.
    let stats = engine.stats();
    assert!(stats.decode_steps > 0);
    assert!(
        stats.decode_live_rows > stats.decode_steps,
        "live rows {} <= steps {}: decode never batched",
        stats.decode_live_rows,
        stats.decode_steps
    );
}

#[test]
fn concurrent_matches_sequential_greedy() {
    // Continuous batching must not change greedy outputs vs one-at-a-time.
    let prompts = ["alpha", "beta gamma", "delta"];
    let mk = |p: &str| {
        let mut r = greedy(p, 6);
        ban_eos(&mut r);
        r
    };
    let mut seq_engine = engine();
    let mut sequential = Vec::new();
    for p in &prompts {
        sequential.push(seq_engine.chat_completion(mk(p)).unwrap().text().to_string());
    }
    let mut conc_engine = engine();
    let mut ids = Vec::new();
    for p in &prompts {
        ids.push(conc_engine.submit(mk(p)).unwrap());
    }
    conc_engine.run_to_completion().unwrap();
    let mut concurrent = vec![String::new(); prompts.len()];
    let (done, _) = drain(&mut conc_engine);
    for (rid, resp) in done {
        let idx = ids.iter().position(|&i| i == rid).unwrap();
        concurrent[idx] = resp.text().to_string();
    }
    assert_eq!(sequential, concurrent);
}

// -- stop strings -----------------------------------------------------------

#[test]
fn stop_strings_truncate_and_finish() {
    let mut engine = engine();
    // Greedy reference output is deterministic; its first character is a
    // guaranteed-hit stop string => empty completion.
    let mut probe = greedy("stop test", 4);
    ban_invisible(&mut probe);
    let full = engine.chat_completion(probe.clone()).unwrap();
    let text = full.text().to_string();
    assert!(!text.is_empty(), "invisible tokens banned => four tokens of text");
    let first_char: String = text.chars().take(1).collect();
    let mut stopped = probe;
    stopped.stop = vec![first_char];
    let resp = engine.chat_completion(stopped).unwrap();
    assert_eq!(resp.text(), "");
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Stop);
}

// -- streaming --------------------------------------------------------------

#[test]
fn streaming_deltas_equal_nonstreaming() {
    let mut stream_engine = engine();
    let mut req = greedy("stream me", 10);
    ban_invisible(&mut req);
    let mut streamed_req = req.clone();
    streamed_req.stream = true;
    let id = stream_engine.submit(streamed_req).unwrap();
    stream_engine.run_to_completion().unwrap();
    let (done, chunks) = drain(&mut stream_engine);
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].0, id);

    let streamed: String = chunks.iter().map(|(_, c)| c.delta.as_str()).collect();
    assert_eq!(streamed, done[0].1.text(), "deltas must concatenate to the text");

    // Final chunk carries the finish reason + usage.
    let last = &chunks.last().expect("at least the final chunk").1;
    assert_eq!(last.finish_reason, Some(FinishReason::Length));
    assert!(last.usage.is_some());

    // And the whole thing equals the non-streaming response.
    let resp = engine().chat_completion(req).unwrap();
    assert_eq!(resp.text(), done[0].1.text());
}

// -- cancellation -----------------------------------------------------------

#[test]
fn abort_mid_decode_emits_abort_finish() {
    // Fast-forward off: the long-literal grammar below is one forced run,
    // which ff would emit to the max_tokens Length finish in the very
    // first step — the abort needs the one-token-per-step baseline to
    // land mid-decode.
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.enable_fast_forward = false;
    let mut engine = MLCEngine::new(&cfg).unwrap();
    // A long-literal grammar pins every step to one token ('a') and is
    // not accepting until 80 bytes — generation cannot stop on its own,
    // so the abort deterministically lands mid-decode.
    let mut req = greedy("long generation", 40);
    req.response_format = ResponseFormat::Grammar(format!("root ::= \"{}\"", "a".repeat(80)));
    let id = engine.submit(req).unwrap();
    for _ in 0..3 {
        engine.step().unwrap();
    }
    engine.abort(id);
    engine.run_to_completion().unwrap();
    let (done, _) = drain(&mut engine);
    let resp = &done.iter().find(|(rid, _)| *rid == id).expect("aborted request resolves").1;
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Abort);
    assert!(resp.usage.completion_tokens >= 1);
    assert!(resp.usage.completion_tokens < 40);
    assert!(resp.text().chars().all(|c| c == 'a'), "{:?}", resp.text());
}

#[test]
fn abort_queued_request_errors() {
    let mut engine = engine();
    let mut req = greedy("never runs", 5);
    ban_eos(&mut req);
    let id = engine.submit(req).unwrap();
    engine.abort(id);
    engine.run_to_completion().unwrap();
    let mut saw = false;
    for ev in engine.poll_events() {
        if let EngineEvent::Error(rid, e) = ev {
            if rid == id {
                saw = true;
                assert_eq!(e.status, 499);
            }
        }
    }
    assert!(saw);
}

// -- structured generation --------------------------------------------------

/// Byte-token id in the reference tokenizer (byte_offset 8).
const fn byte_tok(b: u8) -> u32 {
    8 + b as u32
}

/// Bias the value-level freedom of a JSON grammar toward short
/// derivations: close braces eagerly, avoid unbounded strings/arrays/
/// digit runs. Bias never overrides the *mask* — at states where only a
/// biased-down token is legal it is still picked — so the output stays
/// exactly grammar-conformant; the bias only bounds its length, making
/// the test outcome deterministic instead of hash-lottery-dependent.
fn prefer_short_json(r: &mut ChatCompletionRequest) {
    r.sampling.logit_bias.insert(byte_tok(b'}'), 5.0);
    r.sampling.logit_bias.insert(byte_tok(b'{'), 5.0);
    r.sampling.logit_bias.insert(byte_tok(b'"'), -100.0);
    r.sampling.logit_bias.insert(byte_tok(b'['), -100.0);
    r.sampling.logit_bias.insert(byte_tok(b'-'), -100.0);
    for d in b'0'..=b'9' {
        r.sampling.logit_bias.insert(byte_tok(d), -100.0);
    }
}

/// The shared ok/n schema request: seeded, with a '}' nudge that closes
/// the integer after a few digits (digits stay reachable where the
/// grammar forces them). Shared by the schema test and the capacity-1
/// test, whose equality assertion depends on the requests being
/// identical.
fn schema_request() -> ChatCompletionRequest {
    let schema = r#"{
        "type": "object",
        "properties": {"ok": {"type": "boolean"}, "n": {"type": "integer"}},
        "required": ["ok", "n"]
    }"#;
    let mut req = ChatCompletionRequest::new(MODEL).user("emit json");
    req.max_tokens = 100;
    req.sampling.seed = Some(3);
    req.sampling.logit_bias.insert(byte_tok(b'}'), 5.0);
    req.response_format = ResponseFormat::JsonSchema(parse(schema).unwrap());
    req
}

#[test]
fn structured_generation_json_schema() {
    let mut engine = engine();
    let resp = engine.chat_completion(schema_request()).unwrap();
    let v = parse(resp.text()).unwrap_or_else(|e| panic!("not JSON: {e}: {}", resp.text()));
    assert!(v.get("ok").is_some(), "missing required 'ok': {}", resp.text());
    assert!(v.get("n").is_some(), "missing required 'n': {}", resp.text());
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Stop);
}

#[test]
fn structured_generation_json_object() {
    let mut engine = engine();
    let mut req = ChatCompletionRequest::new(MODEL).user("any json");
    req.max_tokens = 100;
    req.sampling.seed = Some(7);
    prefer_short_json(&mut req);
    req.response_format = ResponseFormat::JsonObject;
    let resp = engine.chat_completion(req).unwrap();
    parse(resp.text()).unwrap_or_else(|e| panic!("not JSON: {e}: {}", resp.text()));
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Stop);
}

#[test]
fn structured_generation_ebnf_choice() {
    let mut engine = engine();
    let mut req = ChatCompletionRequest::new(MODEL).user("yes or no");
    req.max_tokens = 16;
    req.sampling.seed = Some(11);
    req.response_format = ResponseFormat::Grammar(r#"root ::= "yes" | "no""#.into());
    let resp = engine.chat_completion(req).unwrap();
    assert!(
        resp.text() == "yes" || resp.text() == "no",
        "grammar violated: {:?}",
        resp.text()
    );
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Stop);
}

#[test]
fn structured_generation_bounded_number_and_pattern() {
    // The extended keyword families end-to-end: a regex `pattern` and a
    // digit-DFA integer range, decoded through the real masked sampler
    // on the reference backend and checked with the independent JSON
    // parser. The schema is fully bounded, so decoding must terminate
    // with Stop well inside max_tokens.
    let schema = r#"{
        "type": "object",
        "properties": {
            "code": {"type": "string", "pattern": "^[A-Z]{2}-[0-9]{3}$"},
            "score": {"type": "integer", "minimum": 1, "maximum": 40}
        },
        "required": ["code", "score"]
    }"#;
    let mut engine = engine();
    let mut req = ChatCompletionRequest::new(MODEL).user("emit a code and score");
    req.max_tokens = 120;
    req.sampling.seed = Some(5);
    req.sampling.logit_bias.insert(byte_tok(b'}'), 5.0);
    req.response_format = ResponseFormat::JsonSchema(parse(schema).unwrap());
    let resp = engine.chat_completion(req).unwrap();
    let v = parse(resp.text()).unwrap_or_else(|e| panic!("not JSON: {e}: {}", resp.text()));

    let code = v.get("code").and_then(|c| c.as_str()).expect("missing 'code'");
    let b = code.as_bytes();
    assert_eq!(b.len(), 6, "code {code:?} violates ^[A-Z]{{2}}-[0-9]{{3}}$");
    assert!(b[0].is_ascii_uppercase() && b[1].is_ascii_uppercase() && b[2] == b'-');
    assert!(b[3..].iter().all(|c| c.is_ascii_digit()), "bad code {code:?}");

    let score = v.get("score").and_then(|s| s.as_i64()).expect("missing 'score'");
    assert!((1..=40).contains(&score), "score {score} outside [1, 40]");
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Stop);
}

#[test]
fn invalid_grammar_rejected_at_submit() {
    let mut engine = engine();
    let mut req = ChatCompletionRequest::new(MODEL).user("x");
    req.response_format = ResponseFormat::Grammar("root = not-ebnf".into());
    let err = engine.submit(req).unwrap_err();
    assert_eq!(err.status, 400);
}

#[test]
fn mask_cache_capacity_one_still_yields_correct_masks() {
    // Capacity 1 forces an eviction on nearly every state transition; the
    // masks must still constrain decoding correctly.
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.mask_cache_capacity = 1;
    let mut tiny_cache = MLCEngine::new(&cfg).unwrap();
    let resp = tiny_cache.chat_completion(schema_request()).unwrap();
    let v = parse(resp.text()).unwrap_or_else(|e| panic!("not JSON: {e}: {}", resp.text()));
    assert!(v.get("ok").is_some() && v.get("n").is_some());

    let stats = tiny_cache.stats_json();
    let grammar = stats.get("grammar").unwrap();
    let evictions = grammar.get("mask_evictions").unwrap().as_i64().unwrap();
    assert!(evictions > 0, "capacity 1 must evict (saw {evictions})");

    // Same request on a default-capacity engine: identical output — the
    // cache bound is semantically invisible.
    let resp2 = engine().chat_completion(schema_request()).unwrap();
    assert_eq!(resp.text(), resp2.text());
}

// -- logprobs ---------------------------------------------------------------

#[test]
fn logprobs_end_to_end() {
    let mut engine = engine();
    let mut req = greedy("logprob test", 5);
    ban_eos(&mut req);
    req.sampling.logprobs = true;
    req.sampling.top_logprobs = 3;
    let resp = engine.chat_completion(req).unwrap();
    let lps = resp.choices[0].logprobs.as_ref().expect("logprobs requested");
    assert_eq!(lps.len(), 5, "one entry per generated token");
    for entry in lps {
        assert!(entry.logprob <= 0.0);
        assert!(entry.top.len() <= 3);
        // Greedy: the sampled token must be the top-1 alternative.
        if let Some((top_tok, top_lp)) = entry.top.first() {
            assert_eq!(*top_tok, entry.token);
            assert!((top_lp - entry.logprob).abs() < 1e-6);
        }
    }
    // Wire roundtrip preserves logprobs.
    let v = resp.to_json();
    let back = webllm::api::ChatCompletionResponse::from_json(&v).unwrap();
    assert!(back.choices[0].logprobs.is_some());
}

// -- multi-model ------------------------------------------------------------

#[test]
fn multi_model_admission_and_distinct_outputs() {
    let mut engine =
        MLCEngine::new(&EngineConfig::reference(&["tiny-ref", "tiny-ref-b"])).unwrap();
    assert_eq!(engine.loaded_models(), vec!["tiny-ref".to_string(), "tiny-ref-b".to_string()]);

    let prompts = ["one", "two", "three"];
    let mut ids = Vec::new();
    for model in ["tiny-ref", "tiny-ref-b"] {
        for p in &prompts {
            let mut r = ChatCompletionRequest::new(model).user(*p);
            r.max_tokens = 6;
            r.sampling.temperature = 0.0;
            ban_eos(&mut r);
            ids.push((model, engine.submit(r).unwrap()));
        }
    }
    engine.run_to_completion().unwrap();
    let (done, _) = drain(&mut engine);
    assert_eq!(done.len(), 6);
    let text_of = |want: u64| -> String {
        done.iter().find(|(rid, _)| *rid == want).unwrap().1.text().to_string()
    };
    let texts = |model: &str| -> Vec<String> {
        ids.iter().filter(|(m, _)| *m == model).map(|&(_, id)| text_of(id)).collect()
    };
    assert_ne!(texts("tiny-ref"), texts("tiny-ref-b"), "two models must not share logits");

    // Unknown model still rejected synchronously.
    let err = engine.submit(ChatCompletionRequest::new("tiny-2m").user("x")).unwrap_err();
    assert_eq!(err.status, 404);
}

// -- worker / frontend path -------------------------------------------------

#[test]
fn worker_frontend_end_to_end() {
    let mut fe = frontend();
    assert_eq!(fe.models(), &[MODEL.to_string()]);

    // Non-streaming equals the direct engine.
    let mut req = greedy("over the wire", 6);
    ban_invisible(&mut req);
    let resp = fe.chat_completion(req.clone()).unwrap();
    let direct = engine().chat_completion(req.clone()).unwrap();
    assert_eq!(resp.text(), direct.text(), "worker path must match direct");

    // Streaming: chunks concatenate to the full text.
    let mut streamed = String::new();
    let resp2 = fe.chat_completion_stream(req, |c| streamed.push_str(&c.delta)).unwrap();
    assert_eq!(streamed, resp2.text());
    assert!(!streamed.is_empty());

    // Stats round-trip over the wire.
    let stats = fe.stats().unwrap();
    assert!(stats.get("decode_tokens").is_some());
    assert!(stats.get("models").and_then(|m| m.get(MODEL)).is_some());
}

#[test]
fn worker_error_paths() {
    let mut fe = frontend();
    let err = fe
        .chat_completion(ChatCompletionRequest::new("no-such-model").user("x"))
        .unwrap_err();
    assert_eq!(err.status, 404);
    // Oversize prompt: longer than the *context length* (prompts merely
    // longer than the largest compiled chunk are chunked, not rejected —
    // see test_chunked_prefill.rs).
    let long = "word ".repeat(400);
    let err = fe
        .chat_completion(ChatCompletionRequest::new(MODEL).user(long))
        .unwrap_err();
    assert_eq!(err.status, 400);
    // Empty messages.
    let err = fe
        .chat_completion(ChatCompletionRequest::new(MODEL))
        .unwrap_err();
    assert_eq!(err.status, 400);
}

// -- prefix cache -----------------------------------------------------------

#[test]
fn prefix_cache_hits_are_accounted() {
    let mut engine = engine();
    let mk = || {
        let mut r = greedy("a shared prompt prefix for caching", 4);
        ban_eos(&mut r);
        r
    };
    let a = engine.chat_completion(mk()).unwrap();
    let b = engine.chat_completion(mk()).unwrap();
    assert_eq!(a.text(), b.text(), "prefix reuse must not change outputs");

    let stats = engine.stats_json();
    let model = stats.get("models").unwrap().get(MODEL).unwrap();
    let hits = model.get("prefix_cache_hits").unwrap().as_i64().unwrap();
    assert!(hits >= 1, "second identical prompt must hit the prefix cache (hits {hits})");
}

#[test]
fn prefix_cache_disabled_scores_no_hits() {
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.enable_prefix_cache = false;
    let mut engine = MLCEngine::new(&cfg).unwrap();
    let mk = || {
        let mut r = greedy("a shared prompt prefix for caching", 4);
        ban_eos(&mut r);
        r
    };
    let a = engine.chat_completion(mk()).unwrap();
    let b = engine.chat_completion(mk()).unwrap();
    assert_eq!(a.text(), b.text());
    let stats = engine.stats_json();
    let model = stats.get("models").unwrap().get(MODEL).unwrap();
    assert_eq!(model.get("prefix_cache_hits").unwrap().as_i64(), Some(0));
}

// -- engine telemetry -------------------------------------------------------

#[test]
fn stats_json_is_populated_across_subsystems() {
    let mut engine = engine();
    let mut plain = greedy("stats probe", 6);
    ban_eos(&mut plain);
    engine.chat_completion(plain).unwrap();
    let mut constrained = ChatCompletionRequest::new(MODEL).user("json stats");
    constrained.max_tokens = 60;
    constrained.sampling.seed = Some(5);
    constrained.response_format = ResponseFormat::JsonObject;
    engine.chat_completion(constrained).unwrap();

    let stats = engine.stats_json();
    assert!(stats.get("decode_tokens").unwrap().as_i64().unwrap() > 0);
    assert!(stats.get("e2e_requests").unwrap().as_i64().unwrap() >= 2);
    // Chunked-prefill accounting: both prompts fit one chunk => exactly
    // one chunk each; the stall/skip counters exist and start sane.
    assert_eq!(stats.get("prefill_chunks").unwrap().as_i64(), Some(2));
    assert!(stats.get("prefill_cached_tokens_skipped").unwrap().as_i64().unwrap() >= 0);
    assert!(stats.get("decode_stall_chunks").unwrap().as_i64().unwrap() >= 0);
    assert!(stats.get("decode_stall_s").unwrap().as_f64().unwrap() >= 0.0);
    let grammar = stats.get("grammar").unwrap();
    assert!(grammar.get("compiles").unwrap().as_i64().unwrap() >= 1);
    let masks = grammar.get("mask_hits").unwrap().as_i64().unwrap()
        + grammar.get("mask_misses").unwrap().as_i64().unwrap();
    assert!(masks > 0, "constrained decode must consult the mask cache");
    let model = stats.get("models").unwrap().get(MODEL).unwrap();
    assert!(model.get("available_pages").unwrap().as_i64().unwrap() > 0);
    assert_eq!(model.get("running").unwrap().as_i64(), Some(0));
}
