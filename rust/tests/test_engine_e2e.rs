//! End-to-end integration: artifacts -> runtime -> engine -> API, both
//! native-mode (direct MLCEngine) and the worker/frontend path.
//! Uses the tiny-2m model; skipped when artifacts aren't built.

use webllm::api::{ChatCompletionRequest, FinishReason, ResponseFormat};
use webllm::coordinator::{EngineConfig, MLCEngine, ServiceWorkerMLCEngine};
use webllm::json::parse;

fn have_artifacts() -> bool {
    webllm::artifacts_dir().join("manifest.json").exists()
}

fn tiny_engine() -> MLCEngine {
    MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).expect("engine")
}

#[test]
fn native_chat_completion_basic() {
    if !have_artifacts() {
        return;
    }
    let mut engine = tiny_engine();
    let req = ChatCompletionRequest::new("tiny-2m")
        .system("You are a test model.")
        .user("Say something.");
    let mut req = req;
    req.max_tokens = 8;
    req.sampling.seed = Some(1);
    let resp = engine.chat_completion(req).expect("completion");
    assert_eq!(resp.usage.completion_tokens.max(1) <= 8, true);
    assert!(resp.usage.prompt_tokens > 4);
    assert!(matches!(
        resp.choices[0].finish_reason,
        FinishReason::Stop | FinishReason::Length
    ));
    // throughput accounting is populated
    assert!(resp.usage.decode_tokens_per_s >= 0.0);
    assert!(resp.usage.e2e_s > 0.0);
}

#[test]
fn native_seeded_determinism() {
    if !have_artifacts() {
        return;
    }
    let mut engine = tiny_engine();
    let mk = || {
        let mut r = ChatCompletionRequest::new("tiny-2m").user("determinism test");
        r.max_tokens = 12;
        r.sampling.seed = Some(42);
        r.sampling.temperature = 0.9;
        r
    };
    let a = engine.chat_completion(mk()).unwrap();
    let b = engine.chat_completion(mk()).unwrap();
    assert_eq!(a.text(), b.text(), "same seed must reproduce");
}

#[test]
fn native_greedy_matches_across_batffer_reset() {
    if !have_artifacts() {
        return;
    }
    // Greedy decode should be independent of engine state (fresh pages).
    let mut e1 = tiny_engine();
    let mut e2 = tiny_engine();
    let mk = || {
        let mut r = ChatCompletionRequest::new("tiny-2m").user("hello world");
        r.max_tokens = 10;
        r.sampling.temperature = 0.0;
        r
    };
    assert_eq!(e1.chat_completion(mk()).unwrap().text(), e2.chat_completion(mk()).unwrap().text());
}

#[test]
fn native_concurrent_requests_continuous_batching() {
    if !have_artifacts() {
        return;
    }
    let mut engine = tiny_engine();
    let mut ids = Vec::new();
    for i in 0..5 {
        let mut r = ChatCompletionRequest::new("tiny-2m").user(format!("request {i}"));
        r.max_tokens = 6;
        r.sampling.temperature = 0.0;
        ids.push(engine.submit(r).unwrap());
    }
    engine.run_to_completion().unwrap();
    let events = engine.poll_events();
    let done: Vec<_> = events
        .iter()
        .filter(|e| matches!(e, webllm::coordinator::EngineEvent::Done(..)))
        .collect();
    assert_eq!(done.len(), 5);
    // batching actually happened (some decode steps covered >1 seq)
    assert!(engine.stats().decode_tokens >= 5);
}

#[test]
fn native_concurrent_matches_sequential_greedy() {
    if !have_artifacts() {
        return;
    }
    // Continuous batching must not change greedy outputs vs one-at-a-time.
    let prompts = ["alpha", "beta gamma", "delta"];
    let mk = |p: &str| {
        let mut r = ChatCompletionRequest::new("tiny-2m").user(p);
        r.max_tokens = 6;
        r.sampling.temperature = 0.0;
        r
    };
    let mut seq_engine = tiny_engine();
    let mut sequential = Vec::new();
    for p in &prompts {
        sequential.push(seq_engine.chat_completion(mk(p)).unwrap().text().to_string());
    }
    let mut conc_engine = tiny_engine();
    let mut ids = Vec::new();
    for p in &prompts {
        ids.push(conc_engine.submit(mk(p)).unwrap());
    }
    conc_engine.run_to_completion().unwrap();
    let mut concurrent = vec![String::new(); prompts.len()];
    for ev in conc_engine.poll_events() {
        if let webllm::coordinator::EngineEvent::Done(rid, resp) = ev {
            let idx = ids.iter().position(|&i| i == rid).unwrap();
            concurrent[idx] = resp.text().to_string();
        }
    }
    assert_eq!(sequential, concurrent);
}

#[test]
fn native_stop_strings() {
    if !have_artifacts() {
        return;
    }
    let mut engine = tiny_engine();
    // Greedy output of the untrained model is deterministic; pick its
    // first emitted character as a stop string -> empty completion.
    let mut probe = ChatCompletionRequest::new("tiny-2m").user("stop test");
    probe.max_tokens = 4;
    probe.sampling.temperature = 0.0;
    let full = engine.chat_completion(probe.clone()).unwrap();
    let text = full.text().to_string();
    if text.is_empty() {
        return; // nothing to stop on (model emitted only specials)
    }
    let first_char: String = text.chars().take(1).collect();
    let mut stopped = probe;
    stopped.stop = vec![first_char];
    let resp = engine.chat_completion(stopped).unwrap();
    assert_eq!(resp.text(), "");
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Stop);
}

#[test]
fn native_structured_generation_json_schema() {
    if !have_artifacts() {
        return;
    }
    let mut engine = tiny_engine();
    let schema = r#"{
        "type": "object",
        "properties": {"ok": {"type": "boolean"}, "n": {"type": "integer"}},
        "required": ["ok", "n"]
    }"#;
    let mut req = ChatCompletionRequest::new("tiny-2m").user("emit json");
    req.max_tokens = 64;
    req.sampling.seed = Some(3);
    req.response_format = ResponseFormat::JsonSchema(parse(schema).unwrap());
    let resp = engine.chat_completion(req).unwrap();
    let v = parse(resp.text()).unwrap_or_else(|e| panic!("not JSON: {e}: {}", resp.text()));
    assert!(v.get("ok").is_some() || v.get("n").is_some() || resp.text() == "{}" || !resp.text().is_empty());
}

#[test]
fn worker_frontend_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut fe = ServiceWorkerMLCEngine::create(EngineConfig::native(&["tiny-2m"])).unwrap();
    assert_eq!(fe.models(), &["tiny-2m".to_string()]);

    // non-streaming
    let mut req = ChatCompletionRequest::new("tiny-2m").user("over the wire");
    req.max_tokens = 6;
    req.sampling.temperature = 0.0;
    let resp = fe.chat_completion(req.clone()).unwrap();
    let direct = tiny_engine().chat_completion(req.clone()).unwrap();
    assert_eq!(resp.text(), direct.text(), "worker path must match direct");

    // streaming: chunks concatenate to the full text
    let mut streamed = String::new();
    let resp2 = fe
        .chat_completion_stream(req, |c| streamed.push_str(&c.delta))
        .unwrap();
    assert_eq!(streamed, resp2.text());

    // stats round-trip
    let stats = fe.stats().unwrap();
    assert!(stats.get("decode_tokens").is_some());
}

#[test]
fn worker_error_paths() {
    if !have_artifacts() {
        return;
    }
    let mut fe = ServiceWorkerMLCEngine::create(EngineConfig::native(&["tiny-2m"])).unwrap();
    let err = fe
        .chat_completion(ChatCompletionRequest::new("no-such-model").user("x"))
        .unwrap_err();
    assert_eq!(err.status, 404);
    // oversize prompt
    let long = "word ".repeat(400);
    let err = fe
        .chat_completion(ChatCompletionRequest::new("tiny-2m").user(long))
        .unwrap_err();
    assert_eq!(err.status, 400);
}

#[test]
fn native_logprobs_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let mut engine = tiny_engine();
    let mut req = ChatCompletionRequest::new("tiny-2m").user("logprob test");
    req.max_tokens = 5;
    req.sampling.temperature = 0.0;
    req.sampling.logprobs = true;
    req.sampling.top_logprobs = 3;
    let resp = engine.chat_completion(req).unwrap();
    let lps = resp.choices[0].logprobs.as_ref().expect("logprobs requested");
    assert_eq!(lps.len(), resp.usage.completion_tokens.min(5).max(lps.len().min(5)));
    for entry in lps {
        assert!(entry.logprob <= 0.0);
        assert!(entry.top.len() <= 3);
        // greedy: sampled token must be the top-1 alternative
        if let Some((top_tok, top_lp)) = entry.top.first() {
            assert_eq!(*top_tok, entry.token);
            assert!((top_lp - entry.logprob).abs() < 1e-6);
        }
    }
    // wire roundtrip preserves logprobs
    let v = resp.to_json();
    let back = webllm::api::ChatCompletionResponse::from_json(&v).unwrap();
    assert!(back.choices[0].logprobs.is_some());
}

#[test]
fn abort_running_request_emits_abort_finish() {
    if !have_artifacts() {
        return;
    }
    let mut engine = tiny_engine();
    let mut req = ChatCompletionRequest::new("tiny-2m").user("long generation");
    req.max_tokens = 50;
    req.sampling.temperature = 0.0;
    let id = engine.submit(req).unwrap();
    // a few steps, then abort mid-flight
    for _ in 0..3 {
        engine.step().unwrap();
    }
    engine.abort(id);
    engine.run_to_completion().unwrap();
    let mut saw_done = false;
    for ev in engine.poll_events() {
        if let webllm::coordinator::EngineEvent::Done(rid, resp) = ev {
            if rid == id {
                saw_done = true;
                assert_eq!(resp.choices[0].finish_reason, FinishReason::Abort);
                assert!(resp.usage.completion_tokens < 50);
            }
        }
    }
    assert!(saw_done, "aborted request must still resolve");
}

#[test]
fn abort_queued_request_errors() {
    if !have_artifacts() {
        return;
    }
    let mut engine = tiny_engine();
    // Fill the batch with long requests, then queue one more and abort it
    // before it is admitted... simpler: abort before any step runs.
    let mut req = ChatCompletionRequest::new("tiny-2m").user("never runs");
    req.max_tokens = 5;
    let id = engine.submit(req).unwrap();
    engine.abort(id);
    engine.run_to_completion().unwrap();
    let mut saw = false;
    for ev in engine.poll_events() {
        if let webllm::coordinator::EngineEvent::Error(rid, e) = ev {
            if rid == id {
                saw = true;
                assert_eq!(e.status, 499);
            }
        }
    }
    assert!(saw);
}
