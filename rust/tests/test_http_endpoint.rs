//! HTTP endpoint integration: real TCP round-trips against the served
//! engine — non-streaming, streaming (SSE), health, error paths, and
//! concurrent clients.
//!
//! Runs unconditionally on the deterministic reference backend; each
//! test binds its own port so the suites can run in parallel.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;
use webllm::coordinator::EngineConfig;
use webllm::http::{serve, ServerConfig};
use webllm::json::{parse, Value};

const MODEL: &str = "tiny-ref";

fn start_server(
    addr: &'static str,
    max_requests: usize,
) -> std::thread::JoinHandle<Result<(), String>> {
    let cfg = ServerConfig {
        addr: addr.into(),
        engine: EngineConfig::reference(&[MODEL]),
        // Only engine-handled completions count toward the shutdown quota
        // (parse-level 400s and 404s never reach the engine).
        max_requests: Some(max_requests),
    };
    let handle = std::thread::spawn(move || serve(cfg));
    // Wait for readiness via /health.
    for _ in 0..600 {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = write!(s, "GET /health HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n");
            let mut b = String::new();
            let _ = s.read_to_string(&mut b);
            if b.contains("200 OK") {
                return handle;
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("server on {addr} never became healthy");
}

fn post(addr: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn body_of(resp: &str) -> &str {
    resp.split_once("\r\n\r\n").expect("no header/body split").1
}

/// Strict line-by-line SSE parser: every frame must be exactly one
/// `data: ...` line terminated by a blank line, with `data: [DONE]` as
/// the final frame. Returns the parsed JSON events.
fn sse_parse_strict(body: &str) -> (Vec<Value>, bool) {
    let mut events = Vec::new();
    let mut done = false;
    let mut lines = body.lines();
    while let Some(line) = lines.next() {
        if line.is_empty() {
            continue;
        }
        let data = line
            .strip_prefix("data: ")
            .unwrap_or_else(|| panic!("non-SSE line in stream: {line:?}"));
        assert!(!done, "frame after [DONE]: {line:?}");
        if data == "[DONE]" {
            done = true;
        } else {
            events.push(parse(data).unwrap_or_else(|e| panic!("bad SSE json: {e}: {data:?}")));
        }
        assert_eq!(lines.next(), Some(""), "SSE frame not blank-line terminated");
    }
    (events, done)
}

fn content_of(completion: &Value) -> String {
    completion
        .get("choices")
        .and_then(|c| c.at(0))
        .and_then(|c| c.get("message"))
        .and_then(|m| m.get("content"))
        .and_then(Value::as_str)
        .expect("completion has message content")
        .to_string()
}

#[test]
fn endpoint_serves_completions_and_errors() {
    let addr = "127.0.0.1:18091";
    // Quota of 3: two completions + the engine-rejected unknown model
    // (parse-level 400s and route 404s never reach the engine).
    let server = start_server(addr, 3);

    // 1. non-streaming completion
    let resp = post(
        addr,
        "/v1/chat/completions",
        r#"{"model":"tiny-ref","messages":[{"role":"user","content":"hi"}],"max_tokens":5,"temperature":0}"#,
    );
    assert!(resp.contains("200 OK"), "{resp}");
    let v = parse(body_of(&resp)).unwrap();
    assert_eq!(v.get("object").unwrap().as_str(), Some("chat.completion"));
    assert!(v.get("usage").unwrap().get("completion_tokens").unwrap().as_usize().unwrap() <= 5);

    // 2. streaming completion
    let resp = post(
        addr,
        "/v1/chat/completions",
        r#"{"model":"tiny-ref","messages":[{"role":"user","content":"hi"}],"max_tokens":5,"temperature":0,"stream":true}"#,
    );
    assert!(resp.contains("text/event-stream"), "{resp}");
    let (events, done) = sse_parse_strict(body_of(&resp));
    assert!(done, "missing [DONE]");
    assert!(!events.is_empty());
    assert!(events.last().unwrap().get("usage").is_some());

    // 3. bad request -> 400 with OpenAI error shape
    let resp = post(addr, "/v1/chat/completions", r#"{"model":"tiny-ref"}"#);
    assert!(resp.contains("400"), "{resp}");
    assert!(resp.contains("invalid_request_error"));

    // 4. unknown model -> 404 from the reference registry
    let resp = post(
        addr,
        "/v1/chat/completions",
        r#"{"model":"no-such","messages":[{"role":"user","content":"hi"}]}"#,
    );
    assert!(resp.contains("404"), "{resp}");

    // 5. unknown route -> 404
    let resp = post(addr, "/v1/nope", "{}");
    assert!(resp.contains("404"), "{resp}");

    server.join().unwrap().unwrap();
}

#[test]
fn endpoint_sse_stream_matches_nonstreaming() {
    let addr = "127.0.0.1:18092";
    let server = start_server(addr, 2);
    // Ban empty-byte tokens so the text is non-trivial and chunked.
    let base = r#""model":"tiny-ref","messages":[{"role":"user","content":"stream equivalence"}],"max_tokens":10,"temperature":0,"logit_bias":{"0":-100,"1":-100,"2":-100,"3":-100,"4":-100,"5":-100,"6":-100,"7":-100}"#;

    let resp = post(addr, "/v1/chat/completions", &format!("{{{base}}}"));
    assert!(resp.contains("200 OK"), "{resp}");
    let full = parse(body_of(&resp)).unwrap();
    let full_text = content_of(&full);
    assert!(!full_text.is_empty());

    let resp = post(addr, "/v1/chat/completions", &format!("{{{base},\"stream\":true}}"));
    assert!(resp.contains("text/event-stream"), "{resp}");
    let (events, done) = sse_parse_strict(body_of(&resp));
    assert!(done, "missing [DONE] terminator");

    // Every event is a chunk object; deltas concatenate to the
    // non-streaming content; the final chunk carries finish + usage.
    let mut streamed = String::new();
    for ev in &events {
        assert_eq!(ev.get("object").unwrap().as_str(), Some("chat.completion.chunk"));
        if let Some(delta) = ev
            .get("choices")
            .and_then(|c| c.at(0))
            .and_then(|c| c.get("delta"))
            .and_then(|d| d.get("content"))
            .and_then(Value::as_str)
        {
            streamed.push_str(delta);
        }
    }
    assert_eq!(streamed, full_text, "SSE deltas must reassemble the full text");
    let last = events.last().unwrap();
    assert_eq!(
        last.get("choices").unwrap().at(0).unwrap().get("finish_reason").unwrap().as_str(),
        Some("length")
    );
    assert!(last.get("usage").is_some());

    server.join().unwrap().unwrap();
}

#[test]
fn endpoint_structured_generation_over_http() {
    let addr = "127.0.0.1:18093";
    let server = start_server(addr, 1);
    // logit_bias 133 = byte token '}' (+5): closes the integer after few
    // digits so the derivation finishes well inside max_tokens.
    let body = r#"{
        "model":"tiny-ref",
        "messages":[{"role":"user","content":"emit json"}],
        "max_tokens":100,
        "seed":3,
        "logit_bias":{"133":5},
        "response_format":{"type":"json_schema","schema":{
            "type":"object",
            "properties":{"ok":{"type":"boolean"},"n":{"type":"integer"}},
            "required":["ok","n"]
        }}
    }"#;
    let resp = post(addr, "/v1/chat/completions", body);
    assert!(resp.contains("200 OK"), "{resp}");
    let v = parse(body_of(&resp)).unwrap();
    let text = content_of(&v);
    let obj = parse(&text).unwrap_or_else(|e| panic!("not JSON over HTTP: {e}: {text}"));
    assert!(obj.get("ok").is_some() && obj.get("n").is_some(), "{text}");

    server.join().unwrap().unwrap();
}

#[test]
fn endpoint_streaming_submit_errors_return_plain_status_not_sse() {
    // The SSE preamble is deferred until the engine accepts the request:
    // a submit-time failure on a streaming request must come back as a
    // plain HTTP status, never wrapped in a 200 event stream.
    let addr = "127.0.0.1:18095";
    let server = start_server(addr, 1);
    let resp = post(
        addr,
        "/v1/chat/completions",
        r#"{"model":"no-such","messages":[{"role":"user","content":"hi"}],"stream":true}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
    assert!(!resp.contains("text/event-stream"), "{resp}");
    assert!(!resp.contains("data: "), "{resp}");
    assert!(resp.contains("not_found_error"), "{resp}");

    // Burn the quota so the server thread exits.
    let resp = post(
        addr,
        "/v1/chat/completions",
        r#"{"model":"tiny-ref","messages":[{"role":"user","content":"hi"}],"max_tokens":2,"stream":true}"#,
    );
    assert!(resp.contains("text/event-stream"), "{resp}");
    server.join().unwrap().unwrap();
}

#[test]
fn endpoint_back_pressure_returns_429_with_retry_after() {
    // A server with a 1-deep waiting queue and serialized prefill
    // (browser-mode latency widens the window) under a burst of
    // streaming clients: overflow submits get a plain 429 + Retry-After,
    // admitted ones stream normally.
    let addr = "127.0.0.1:18096";
    let mut engine = EngineConfig::reference_browser(&[MODEL]);
    engine.max_waiting_requests = 1;
    engine.max_concurrent_prefills = 1;
    engine.prefill_token_budget = 16;
    engine.adaptive_prefill = false;
    let cfg = ServerConfig { addr: addr.into(), engine, max_requests: None };
    std::thread::spawn(move || serve(cfg));
    for _ in 0..600 {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = write!(s, "GET /health HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n");
            let mut b = String::new();
            let _ = s.read_to_string(&mut b);
            if b.contains("200 OK") {
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }

    // 100 'x's => a 104-token prompt, 7 serialized chunks at budget 16.
    let body = format!(
        r#"{{"model":"tiny-ref","messages":[{{"role":"user","content":"{}"}}],"max_tokens":3,"temperature":0,"stream":true}}"#,
        "x".repeat(100)
    );
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let body = body.clone();
            std::thread::spawn(move || post(addr, "/v1/chat/completions", &body))
        })
        .collect();
    let responses: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let mut ok = 0;
    let mut rejected = 0;
    for resp in &responses {
        if resp.starts_with("HTTP/1.1 429") {
            rejected += 1;
            // Plain JSON rejection, never an event stream, with the
            // structured error and the back-off hint.
            assert!(resp.contains("Retry-After: 1"), "{resp}");
            assert!(!resp.contains("text/event-stream"), "{resp}");
            assert!(resp.contains("queue_full"), "{resp}");
        } else {
            ok += 1;
            assert!(resp.contains("text/event-stream"), "{resp}");
            let (events, done) = sse_parse_strict(body_of(resp));
            assert!(done, "admitted stream missing [DONE]");
            assert!(!events.is_empty());
        }
    }
    assert!(ok >= 1, "no request was ever admitted");
    assert!(
        rejected >= 1,
        "8 concurrent clients against a 1-deep queue produced no 429s"
    );
}

#[test]
fn endpoint_concurrent_clients_batch() {
    let addr = "127.0.0.1:18094";
    let server = start_server(addr, 4);
    let mk_body = |prompt: &str| {
        format!(
            r#"{{"model":"tiny-ref","messages":[{{"role":"user","content":"{prompt}"}}],"max_tokens":6,"temperature":0,"logit_bias":{{"2":-100,"7":-100}}}}"#
        )
    };
    // Two distinct prompts, each posted twice, all in flight at once.
    let prompts = ["client one", "client two", "client one", "client two"];
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            let body = mk_body(p);
            std::thread::spawn(move || post(addr, "/v1/chat/completions", &body))
        })
        .collect();
    let responses: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let mut texts = Vec::new();
    for resp in &responses {
        assert!(resp.contains("200 OK"), "{resp}");
        let v = parse(body_of(resp)).unwrap();
        assert_eq!(
            v.get("usage").unwrap().get("completion_tokens").unwrap().as_usize(),
            Some(6)
        );
        texts.push(content_of(&v));
    }
    // Identical prompts produce identical greedy completions even under
    // concurrent batching.
    assert_eq!(texts[0], texts[2]);
    assert_eq!(texts[1], texts[3]);

    server.join().unwrap().unwrap();
}
