//! HTTP endpoint integration: real TCP round-trips against the served
//! engine — non-streaming, streaming (SSE), health, and error paths.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;
use webllm::coordinator::EngineConfig;
use webllm::http::{serve, sse_parse, ServerConfig};
use webllm::json::parse;

fn have_artifacts() -> bool {
    webllm::artifacts_dir().join("manifest.json").exists()
}

fn post(addr: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

#[test]
fn endpoint_serves_completions_and_errors() {
    if !have_artifacts() {
        return;
    }
    let addr = "127.0.0.1:18091";
    let cfg = ServerConfig {
        addr: addr.into(),
        engine: EngineConfig::native(&["tiny-2m"]),
        // Only engine-handled completions count toward the shutdown quota
        // (parse-level 400s and 404s never reach the engine).
        max_requests: Some(2),
    };
    let server = std::thread::spawn(move || serve(cfg));

    // wait for readiness via /health
    for _ in 0..600 {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = write!(s, "GET /health HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n");
            let mut b = String::new();
            let _ = s.read_to_string(&mut b);
            if b.contains("200 OK") {
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(200));
    }

    // 1. non-streaming completion
    let resp = post(
        addr,
        "/v1/chat/completions",
        r#"{"model":"tiny-2m","messages":[{"role":"user","content":"hi"}],"max_tokens":5,"temperature":0}"#,
    );
    assert!(resp.contains("200 OK"), "{resp}");
    let body = resp.split_once("\r\n\r\n").unwrap().1;
    let v = parse(body).unwrap();
    assert_eq!(v.get("object").unwrap().as_str(), Some("chat.completion"));
    assert!(v.get("usage").unwrap().get("completion_tokens").unwrap().as_usize().unwrap() <= 5);

    // 2. streaming completion
    let resp = post(
        addr,
        "/v1/chat/completions",
        r#"{"model":"tiny-2m","messages":[{"role":"user","content":"hi"}],"max_tokens":5,"temperature":0,"stream":true}"#,
    );
    assert!(resp.contains("text/event-stream"), "{resp}");
    let body = resp.split_once("\r\n\r\n").unwrap().1;
    let (events, done) = sse_parse(body);
    assert!(done, "missing [DONE]");
    assert!(!events.is_empty());
    assert!(events
        .last()
        .unwrap()
        .get("usage")
        .is_some());

    // 3. bad request -> 400 with OpenAI error shape
    let resp = post(addr, "/v1/chat/completions", r#"{"model":"tiny-2m"}"#);
    assert!(resp.contains("400"), "{resp}");
    assert!(resp.contains("invalid_request_error"));

    // 4. unknown route -> 404
    let resp = post(addr, "/v1/nope", "{}");
    assert!(resp.contains("404"), "{resp}");

    server.join().unwrap().unwrap();
}
