//! JSON-Schema grammar conformance suite.
//!
//! Three layers, per ROADMAP item 3 (llguidance-style conformance):
//!
//! 1. A fixture corpus (`tests/corpus/*.json`, `{schema, valid, invalid}`)
//!    driven through BOTH the byte-level grammar matcher and the
//!    independent oracle validator (`testutil::schema_oracle`), plus an
//!    AOT compile sanity check per fixture.
//! 2. A differential property test: a seeded generator emits a random
//!    supported schema together with a canonical conforming instance;
//!    the grammar must accept it and the oracle must validate it. Byte-
//!    and structure-level mutants that the oracle rejects (or that are
//!    not JSON at all) must be rejected by the grammar.
//! 3. Keyword coverage accounting: the corpus must exercise every
//!    supported keyword, and the suite fails if one goes missing.
//!
//! The grammar emits a canonical *subset* of each schema's language
//! (compact bytes, schema-ordered properties), so `oracle_only` fixture
//! entries capture instances that validate but are not canonical.

use std::collections::BTreeMap;
use std::rc::Rc;

use webllm::grammar::{
    schema_to_grammar, CompiledGrammar, Grammar, GrammarError, GrammarMatcher, VocabTrie,
};
use webllm::json::{parse, to_string, Value};
use webllm::testutil::prop::{PropRng, Runner};
use webllm::testutil::schema_oracle;

/// Every keyword the compiler supports; the corpus must cover each.
const REQUIRED_KEYWORDS: &[&str] = &[
    "type",
    "enum",
    "const",
    "anyOf",
    "oneOf",
    "allOf",
    "$ref",
    "properties",
    "required",
    "additionalProperties",
    "items",
    "prefixItems",
    "minItems",
    "maxItems",
    "minLength",
    "maxLength",
    "pattern",
    "format",
    "minimum",
    "maximum",
    "exclusiveMinimum",
    "exclusiveMaximum",
];

fn corpus() -> Vec<(String, Value)> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/corpus");
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(&dir)
        .expect("tests/corpus must exist")
        .map(|e| e.expect("read_dir entry").path())
        .filter(|p| p.extension().map_or(false, |x| x == "json"))
        .collect();
    files.sort();
    assert!(!files.is_empty(), "empty corpus directory");
    files
        .into_iter()
        .map(|p| {
            let text = std::fs::read_to_string(&p).expect("read corpus file");
            let doc = parse(&text).unwrap_or_else(|e| panic!("{}: {e}", p.display()));
            (p.file_name().unwrap().to_string_lossy().into_owned(), doc)
        })
        .collect()
}

fn byte_vocab() -> Vec<Vec<u8>> {
    (0u16..=255).map(|b| vec![b as u8]).collect()
}

fn accepts(g: &Rc<Grammar>, bytes: &[u8]) -> bool {
    let mut m = GrammarMatcher::new(g.clone());
    m.advance_bytes(bytes) && m.is_accepting()
}

fn list<'a>(fx: &'a Value, key: &str) -> &'a [Value] {
    fx.get(key).and_then(Value::as_array).map_or(&[], |a| a.as_slice())
}

#[test]
fn schema_conformance_corpus() {
    let vocab = byte_vocab();
    let trie = VocabTrie::build(vocab.len(), |i| vocab[i as usize].as_slice());
    let mut tally: BTreeMap<String, usize> = BTreeMap::new();
    let mut fixtures = 0usize;
    let mut instances = 0usize;

    for (file, doc) in corpus() {
        for fx in doc.as_array().unwrap_or_else(|| panic!("{file}: not an array")) {
            fixtures += 1;
            let name = fx.get("name").and_then(Value::as_str).unwrap_or("?");
            let ctx = format!("{file} :: {name}");
            for k in list(fx, "keywords") {
                let k = k.as_str().expect("keywords must be strings");
                *tally.entry(k.to_string()).or_default() += 1;
            }
            let schema = fx.get("schema").unwrap_or_else(|| panic!("[{ctx}] missing schema"));

            if fx.get("error").and_then(Value::as_bool).unwrap_or(false) {
                match schema_to_grammar(schema) {
                    Err(GrammarError::Schema(_)) => {}
                    Err(other) => panic!("[{ctx}] expected Schema error, got {other:?}"),
                    Ok(_) => panic!("[{ctx}] expected Schema error, schema compiled"),
                }
                continue;
            }

            let g = Rc::new(
                schema_to_grammar(schema).unwrap_or_else(|e| panic!("[{ctx}] compile: {e}")),
            );
            // Every supported keyword flows through the AOT pass, and the
            // byte-level base partition is never trivial (e.g. control
            // bytes can never appear in compact JSON).
            let compiled =
                CompiledGrammar::compile(g.clone(), &trie, |i| vocab[i as usize].as_slice());
            assert!(
                compiled.base_reject().count_allowed() > 0,
                "[{ctx}] AOT pass found no context-independent rejects"
            );

            for v in list(fx, "valid") {
                instances += 1;
                let bytes = to_string(v);
                let oracle = schema_oracle::validate(schema, v)
                    .unwrap_or_else(|e| panic!("[{ctx}] oracle: {e}"));
                assert!(oracle, "[{ctx}] oracle rejected valid instance {bytes}");
                assert!(
                    accepts(&g, bytes.as_bytes()),
                    "[{ctx}] grammar rejected valid instance {bytes}"
                );
            }
            for v in list(fx, "invalid") {
                instances += 1;
                let bytes = to_string(v);
                let oracle = schema_oracle::validate(schema, v)
                    .unwrap_or_else(|e| panic!("[{ctx}] oracle: {e}"));
                assert!(!oracle, "[{ctx}] oracle accepted invalid instance {bytes}");
                assert!(
                    !accepts(&g, bytes.as_bytes()),
                    "[{ctx}] grammar accepted invalid instance {bytes}"
                );
            }
            // Valid per the spec (oracle) but outside the canonical
            // subset the grammar emits (key order, unanchored pattern).
            for v in list(fx, "oracle_only") {
                instances += 1;
                let oracle = schema_oracle::validate(schema, v)
                    .unwrap_or_else(|e| panic!("[{ctx}] oracle: {e}"));
                assert!(oracle, "[{ctx}] oracle rejected oracle_only instance");
            }
        }
    }

    assert!(fixtures >= 40, "corpus too small: {fixtures} fixtures (need >= 40)");
    let missing: Vec<&str> = REQUIRED_KEYWORDS
        .iter()
        .copied()
        .filter(|k| !tally.contains_key(*k))
        .collect();
    assert!(missing.is_empty(), "keywords with no corpus coverage: {missing:?}");

    println!("schema conformance: {fixtures} fixtures, {instances} instances");
    println!("per-keyword fixture tally:");
    for (k, n) in &tally {
        println!("  {k:<24} {n}");
    }
}

// --- differential property test ------------------------------------------

/// A randomly generated supported schema plus one canonical conforming
/// instance (generated together so the pair is correct by construction).
fn gen_pair(rng: &mut PropRng, depth: usize) -> (Value, Value) {
    // Past depth 2 only scalar shapes, so instances stay small.
    let arm = if depth >= 2 { rng.range(9) } else { rng.range(16) };
    match arm {
        // Bounded integer (inclusive/exclusive mix).
        0 => {
            let a = rng.i64_in(-999, 999);
            let b = a + rng.i64_in(0, 500);
            let mut s = webllm::json::Map::new();
            s.insert("type", "integer");
            if rng.bool() {
                s.insert("minimum", a);
            } else {
                s.insert("exclusiveMinimum", a - 1);
            }
            if rng.bool() {
                s.insert("maximum", b);
            } else {
                s.insert("exclusiveMaximum", b + 1);
            }
            let inst = rng.i64_in(a, b);
            (Value::Object(s), Value::Number(inst as f64))
        }
        // Bounded number: integer or mid-interval decimal.
        1 => {
            let a = rng.i64_in(-999, 999);
            let b = a + rng.i64_in(1, 500);
            let mut s = webllm::json::Map::new();
            s.insert("type", "number");
            s.insert("minimum", a);
            s.insert("maximum", b);
            let inst = if rng.bool() {
                rng.i64_in(a, b) as f64
            } else {
                rng.i64_in(a, b - 1) as f64 + 0.5
            };
            (Value::Object(s), Value::Number(inst))
        }
        // Plain string (escapes, unicode).
        2 => {
            let mut s = webllm::json::Map::new();
            s.insert("type", "string");
            let inst = rng.string(6);
            (Value::Object(s), Value::String(inst))
        }
        // Length-bounded string counting code points.
        3 => {
            let min = rng.range(4);
            let max = min + rng.range(4);
            let mut s = webllm::json::Map::new();
            s.insert("type", "string");
            s.insert("minLength", min);
            s.insert("maxLength", max);
            let len = min + rng.range(max - min + 1);
            let pool = ['a', 'Z', '5', '_', 'é', '日', '😀'];
            let inst: String = (0..len).map(|_| *rng.choose(&pool)).collect();
            (Value::Object(s), Value::String(inst))
        }
        // Pattern from a pool, sample generated alongside.
        4 => {
            let pick = rng.range(4);
            let (pat, sample): (&str, String) = match pick {
                0 => {
                    let len = 2 + rng.range(3);
                    let s: String =
                        (0..len).map(|_| (b'a' + rng.range(26) as u8) as char).collect();
                    ("^[a-z]{2,4}$", s)
                }
                1 => {
                    let mut s = String::new();
                    s.push((b'A' + rng.range(26) as u8) as char);
                    for _ in 0..1 + rng.range(4) {
                        s.push((b'0' + rng.range(10) as u8) as char);
                    }
                    ("^[A-Z][0-9]+$", s)
                }
                2 => {
                    let mut s = String::new();
                    for _ in 0..1 + rng.range(3) {
                        s.push_str(if rng.bool() { "ab" } else { "cd" });
                    }
                    ("^(ab|cd)+$", s)
                }
                _ => {
                    let mut s = String::from("x");
                    for _ in 0..3 {
                        s.push((b'0' + rng.range(10) as u8) as char);
                    }
                    s.push('-');
                    for _ in 0..2 {
                        s.push((b'a' + rng.range(6) as u8) as char);
                    }
                    ("^x[0-9]{3}-[a-f]{2}$", s)
                }
            };
            let mut s = webllm::json::Map::new();
            s.insert("type", "string");
            s.insert("pattern", pat);
            (Value::Object(s), Value::String(sample))
        }
        // Format: uuid or date.
        5 => {
            let mut s = webllm::json::Map::new();
            s.insert("type", "string");
            let inst = if rng.bool() {
                s.insert("format", "uuid");
                let hex = |rng: &mut PropRng, n: usize| -> String {
                    (0..n)
                        .map(|_| {
                            let d = rng.range(16);
                            char::from_digit(d as u32, 16).unwrap()
                        })
                        .collect()
                };
                format!(
                    "{}-{}-{}-{}-{}",
                    hex(rng, 8),
                    hex(rng, 4),
                    hex(rng, 4),
                    hex(rng, 4),
                    hex(rng, 12)
                )
            } else {
                s.insert("format", "date");
                format!(
                    "{:04}-{:02}-{:02}",
                    1900 + rng.range(200),
                    1 + rng.range(12),
                    1 + rng.range(28)
                )
            };
            (Value::Object(s), Value::String(inst))
        }
        6 => {
            let mut s = webllm::json::Map::new();
            s.insert("type", "boolean");
            (Value::Object(s), Value::Bool(rng.bool()))
        }
        7 => {
            let mut s = webllm::json::Map::new();
            s.insert("type", "null");
            (Value::Object(s), Value::Null)
        }
        // Scalar enum.
        8 => {
            let n = 2 + rng.range(3);
            let opts: Vec<Value> = (0..n)
                .map(|i| {
                    if rng.bool() {
                        Value::String(format!("opt{i}"))
                    } else {
                        Value::Number((i as i64 * 17 - 5) as f64)
                    }
                })
                .collect();
            let inst = rng.choose(&opts).clone();
            let mut s = webllm::json::Map::new();
            s.insert("enum", Value::Array(opts));
            (Value::Object(s), inst)
        }
        // Object with required/optional properties (schema order).
        9 => {
            let n = 1 + rng.range(3);
            let mut props = webllm::json::Map::new();
            let mut required = Vec::new();
            let mut inst = webllm::json::Map::new();
            for i in 0..n {
                let name = format!("p{i}");
                let (sub_s, sub_i) = gen_pair(rng, depth + 1);
                props.insert(name.clone(), sub_s);
                let req = rng.bool();
                if req {
                    required.push(Value::String(name.clone()));
                }
                if req || rng.bool() {
                    inst.insert(name, sub_i);
                }
            }
            let mut s = webllm::json::Map::new();
            s.insert("type", "object");
            s.insert("properties", Value::Object(props));
            if !required.is_empty() {
                s.insert("required", Value::Array(required));
            }
            (Value::Object(s), Value::Object(inst))
        }
        // Typed map via additionalProperties.
        10 => {
            let (sub_s, sub_i) = gen_pair(rng, depth + 1);
            let mut s = webllm::json::Map::new();
            s.insert("type", "object");
            s.insert("additionalProperties", sub_s);
            let mut inst = webllm::json::Map::new();
            for i in 0..rng.range(4) {
                inst.insert(format!("k{i}"), sub_i.clone());
            }
            (Value::Object(s), Value::Object(inst))
        }
        // Homogeneous array with optional bounds.
        11 => {
            let (sub_s, sub_i) = gen_pair(rng, depth + 1);
            let min = rng.range(2);
            let len = min + rng.range(3);
            let mut s = webllm::json::Map::new();
            s.insert("type", "array");
            s.insert("items", sub_s);
            if min > 0 {
                s.insert("minItems", min);
            }
            if rng.bool() {
                s.insert("maxItems", min + 3);
            }
            let inst: Vec<Value> = (0..len).map(|_| sub_i.clone()).collect();
            (Value::Object(s), Value::Array(inst))
        }
        // Closed tuple via prefixItems + items:false.
        12 => {
            let n = 1 + rng.range(3);
            let mut prefix = Vec::new();
            let mut inst = Vec::new();
            for _ in 0..n {
                let (sub_s, sub_i) = gen_pair(rng, depth + 1);
                prefix.push(sub_s);
                inst.push(sub_i);
            }
            let mut s = webllm::json::Map::new();
            s.insert("type", "array");
            s.insert("prefixItems", Value::Array(prefix));
            s.insert("items", false);
            (Value::Object(s), Value::Array(inst))
        }
        // Nullable type union.
        13 => {
            let t = *rng.choose(&["string", "integer", "boolean"]);
            let mut s = webllm::json::Map::new();
            s.insert("type", Value::Array(vec![Value::from(t), Value::from("null")]));
            let inst = if rng.bool() {
                Value::Null
            } else {
                match t {
                    "string" => Value::String(rng.string(5)),
                    "integer" => Value::Number(rng.i64_in(-100, 100) as f64),
                    _ => Value::Bool(rng.bool()),
                }
            };
            (Value::Object(s), inst)
        }
        // oneOf over disjoint types.
        14 => {
            let mut s = webllm::json::Map::new();
            let branches = vec![
                parse(r#"{"type":"integer"}"#).unwrap(),
                parse(r#"{"type":"string"}"#).unwrap(),
            ];
            s.insert("oneOf", Value::Array(branches));
            let inst = if rng.bool() {
                Value::Number(rng.i64_in(-500, 500) as f64)
            } else {
                Value::String(rng.string(5))
            };
            (Value::Object(s), inst)
        }
        // allOf merging numeric bounds.
        _ => {
            let a = rng.i64_in(-99, 99);
            let b = a + rng.i64_in(0, 100);
            let mut lo = webllm::json::Map::new();
            lo.insert("minimum", a);
            let mut hi = webllm::json::Map::new();
            hi.insert("maximum", b);
            let mut s = webllm::json::Map::new();
            s.insert("type", "integer");
            s.insert("allOf", Value::Array(vec![Value::Object(lo), Value::Object(hi)]));
            (Value::Object(s), Value::Number(rng.i64_in(a, b) as f64))
        }
    }
}

/// Mutate one byte of a serialized instance.
fn mutate_bytes(rng: &mut PropRng, bytes: &[u8]) -> Vec<u8> {
    let mut out = bytes.to_vec();
    if out.is_empty() {
        return out;
    }
    let pool: &[u8] = b"0197azAZ\"{}[],:.-xq ";
    let idx = rng.range(out.len());
    out[idx] = pool[rng.range(pool.len())];
    out
}

/// Replace a random subtree of the instance with a wrong-shaped scalar,
/// or drop/append container entries.
fn mutate_value(rng: &mut PropRng, v: &mut Value) {
    let descend = rng.bool();
    match v {
        Value::Object(o) if descend && !o.is_empty() => {
            let keys: Vec<String> = o.keys().cloned().collect();
            let k = rng.choose(&keys).clone();
            if rng.range(4) == 0 {
                o.remove(&k);
            } else {
                mutate_value(rng, o.get_mut(&k).unwrap());
            }
        }
        Value::Array(items) if descend && !items.is_empty() => {
            let i = rng.range(items.len());
            if rng.range(4) == 0 {
                items.remove(i);
            } else {
                mutate_value(rng, &mut items[i]);
            }
        }
        _ => {
            *v = match rng.range(4) {
                0 => Value::Null,
                1 => Value::Bool(true),
                2 => Value::Number(987654321.0),
                _ => Value::String("§mutant§".into()),
            };
        }
    }
}

#[test]
fn schema_differential_property() {
    Runner::new("schema_differential", 150).run(|rng| {
        let (schema, inst) = gen_pair(rng, 0);
        let sctx = to_string(&schema);
        let g = Rc::new(
            schema_to_grammar(&schema)
                .map_err(|e| format!("compile failed for {sctx}: {e}"))?,
        );
        let bytes = to_string(&inst);
        match schema_oracle::validate(&schema, &inst) {
            Ok(true) => {}
            Ok(false) => return Err(format!("oracle rejected generated {bytes} for {sctx}")),
            Err(e) => return Err(format!("oracle error for {sctx}: {e}")),
        }
        if !accepts(&g, bytes.as_bytes()) {
            return Err(format!("grammar rejected generated {bytes} for {sctx}"));
        }

        // Byte-level mutants: anything that no longer validates (or no
        // longer parses as JSON at all) must be grammar-rejected.
        for _ in 0..4 {
            let mutant = mutate_bytes(rng, bytes.as_bytes());
            if mutant == bytes.as_bytes() {
                continue;
            }
            let oracle_ok = match std::str::from_utf8(&mutant).ok().and_then(|s| parse(s).ok()) {
                Some(mv) => schema_oracle::validate(&schema, &mv)
                    .map_err(|e| format!("oracle error on mutant: {e}"))?,
                None => false,
            };
            if !oracle_ok && accepts(&g, &mutant) {
                return Err(format!(
                    "grammar accepted oracle-rejected mutant {:?} of {bytes} for {sctx}",
                    String::from_utf8_lossy(&mutant)
                ));
            }
        }

        // Structural mutants: replace/drop subtrees, then re-serialize.
        for _ in 0..2 {
            let mut mutant = inst.clone();
            mutate_value(rng, &mut mutant);
            let mbytes = to_string(&mutant);
            let oracle_ok = schema_oracle::validate(&schema, &mutant)
                .map_err(|e| format!("oracle error on structural mutant: {e}"))?;
            if !oracle_ok && accepts(&g, mbytes.as_bytes()) {
                return Err(format!(
                    "grammar accepted oracle-rejected structural mutant {mbytes} for {sctx}"
                ));
            }
        }
        Ok(())
    });
}
