//! Parallel sampling (`n > 1`) via page-level copy-on-write KV forking:
//! prefill once, decode many.
//!
//! The load-bearing properties, all on the deterministic reference
//! backend (no artifacts, runs everywhere):
//!   (a) every choice of an `n>1` request is byte-identical to an
//!       independent `n=1` request carrying that branch's derived seed —
//!       the fork is a scheduling optimization, never an output one;
//!   (b) the family runs exactly one prefill pass over the prompt
//!       (prefill-token accounting) and shares full prompt pages by
//!       refcount (fork/CoW stats);
//!   (c) the identity in (a) survives randomized preemption schedules,
//!       grammar fast-forward, and speculative decoding;
//!   (d) streamed families partition their chunks by choice `index`;
//!   (e) aborts resolve the whole family without leaking pages, and a
//!       finished family seeds the prefix cache for O(new-tokens)
//!       follow-up sessions.

use webllm::api::{ChatCompletionRequest, FinishReason, ResponseFormat};
use webllm::coordinator::{EngineConfig, EngineEvent, MLCEngine, RequestId};
use webllm::json::{parse, Value};
use webllm::sampler::branch_seed;
use webllm::testutil::ban_reference_eos as ban_eos;
use webllm::testutil::prop::Runner;

const MODEL: &str = "tiny-ref";
/// Different depth/pool: a genuinely divergent drafter, so rejection
/// paths run under speculation.
const DRAFT: &str = "tiny-ref-b";

fn engine() -> MLCEngine {
    MLCEngine::new(&EngineConfig::reference(&[MODEL])).expect("engine")
}

/// Seeded sampling request over `'x' * k` (k + 4 prompt tokens).
fn xs_request(k: usize, max_tokens: usize, seed: u64, temperature: f32) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new(MODEL).user("x".repeat(k));
    r.max_tokens = max_tokens;
    r.sampling.seed = Some(seed);
    r.sampling.temperature = temperature;
    ban_eos(&mut r);
    r
}

fn stat_i64(engine: &MLCEngine, key: &str) -> i64 {
    engine.stats_json().get(key).unwrap().as_i64().unwrap()
}

fn model_stat(engine: &MLCEngine, key: &str) -> i64 {
    engine
        .stats_json()
        .get("models")
        .and_then(|m| m.get(MODEL))
        .and_then(|m| m.get(key))
        .and_then(Value::as_i64)
        .unwrap()
}

/// Drive `engine` to completion, preempting one of `id`'s branches
/// whenever `when` says so, and return `id`'s response. Bounded so a
/// scheduling bug fails loudly instead of hanging the suite.
fn run_family_with_preemption(
    engine: &mut MLCEngine,
    id: RequestId,
    mut when: impl FnMut(usize) -> bool,
) -> webllm::api::ChatCompletionResponse {
    for step in 0..800 {
        if when(step) {
            engine.preempt(id);
        }
        engine.step().expect("step");
        for ev in engine.poll_events() {
            match ev {
                EngineEvent::Done(rid, resp) if rid == id => return resp,
                EngineEvent::Error(rid, e) if rid == id => panic!("family failed: {e}"),
                _ => {}
            }
        }
        if !engine.has_work() {
            break;
        }
    }
    panic!("family did not complete within 800 steps");
}

// -- (a)+(b) choice-level byte identity + single-prefill accounting ----------

#[test]
fn prop_each_choice_matches_an_independent_seeded_request() {
    // Random prompt length, temperature, seed, and fan-out width: choice
    // `i` of an n-way request must be byte-identical to a solo request
    // seeded with `branch_seed(seed, i)` (branch 0 IS the plain seed),
    // while the family prefills the prompt exactly once.
    Runner::new("fork_choice_equivalence", 5).run(|rng| {
        let k = rng.range(61);
        let seed = rng.u64();
        let temperature = 0.2 + rng.f64() as f32;
        let n = 2 + rng.range(3);

        let mut want = Vec::new();
        for i in 0..n {
            let solo = engine()
                .chat_completion(xs_request(k, 6, branch_seed(seed, i), temperature))
                .map_err(|e| e.to_string())?;
            want.push(solo);
        }

        let mut e = engine();
        let resp = e
            .chat_completion(xs_request(k, 6, seed, temperature).with_n(n))
            .map_err(|e| e.to_string())?;
        if resp.choices.len() != n {
            return Err(format!("asked for {n} choices, got {}", resp.choices.len()));
        }
        for (i, choice) in resp.choices.iter().enumerate() {
            if choice.index != i {
                return Err(format!("choice {i} carries index {}", choice.index));
            }
            if choice.content != want[i].text() {
                return Err(format!(
                    "choice {i} (n={n}, k={k}) {:?} != independent run {:?}",
                    choice.content,
                    want[i].text()
                ));
            }
        }
        // One prefill pass for the whole family: prompt tokens computed
        // once, not n times, and every extra branch is a recorded fork.
        if stat_i64(&e, "prefill_tokens") != (k + 4) as i64 {
            return Err(format!(
                "family recomputed the prompt: {} prefill tokens for a {}-token prompt",
                stat_i64(&e, "prefill_tokens"),
                k + 4
            ));
        }
        if stat_i64(&e, "forks") != (n - 1) as i64 {
            return Err(format!("expected {} forks, saw {}", n - 1, stat_i64(&e, "forks")));
        }
        // Usage aggregates across the family: prompt counted once,
        // completions summed over branches.
        if resp.usage.prompt_tokens != k + 4 {
            return Err(format!("family prompt_tokens {} != {}", resp.usage.prompt_tokens, k + 4));
        }
        let sum: usize = want.iter().map(|w| w.usage.completion_tokens).sum();
        if resp.usage.completion_tokens != sum {
            return Err(format!(
                "family completion_tokens {} != summed branches {sum}",
                resp.usage.completion_tokens
            ));
        }
        Ok(())
    });
}

#[test]
fn greedy_family_prefills_once_and_shares_pages() {
    // Deterministic spot check with exact stats: a 62-token prompt spans
    // 7 full pages (shared by refcount across the family) plus a partial
    // tail page (copied per branch — the reference backend implements
    // the page-copy primitive, so each fork queues one physical copy).
    let baseline = engine().chat_completion(xs_request(58, 6, 7, 0.0)).unwrap();

    let mut e = engine();
    let idle_pages = model_stat(&e, "available_pages");
    let resp = e.chat_completion(xs_request(58, 6, 7, 0.0).with_n(4)).unwrap();
    assert_eq!(resp.choices.len(), 4);
    for choice in &resp.choices {
        // Greedy sampling draws no RNG: every branch must agree with the
        // solo greedy run exactly.
        assert_eq!(choice.content, baseline.text(), "choice {} diverged", choice.index);
        assert_eq!(choice.finish_reason, FinishReason::Length);
    }
    assert_eq!(stat_i64(&e, "prefill_tokens"), 62, "prompt must be prefilled exactly once");
    assert_eq!(stat_i64(&e, "forks"), 3);
    assert!(stat_i64(&e, "shared_pages") >= 7, "full prompt pages must be refcount-shared");
    assert!(stat_i64(&e, "cow_page_copies") >= 3, "each fork copies the partial tail page");
    // Nothing in flight: every page is allocatable again (free or
    // prefix-cached, both count).
    assert!(!e.has_work());
    assert_eq!(model_stat(&e, "available_pages"), idle_pages, "family leaked pages");
}

// -- (c) identity survives preemption + speculation + grammar ----------------

#[test]
fn prop_fork_identity_survives_random_preemption_schedules() {
    // Evicting individual branches mid-decode (recompute-on-resume) must
    // not change any choice: divergent tokens live in branch-private
    // pages, shared prompt pages are refcounted, and the sampler state
    // survives eviction.
    Runner::new("fork_preemption_equivalence", 5).run(|rng| {
        let k = rng.range(71);
        let seed = rng.u64();
        let temperature = 0.2 + rng.f64() as f32;

        let mut want = Vec::new();
        for i in 0..3 {
            let solo = engine()
                .chat_completion(xs_request(k, 5, branch_seed(seed, i), temperature))
                .map_err(|e| e.to_string())?;
            want.push(solo);
        }

        let schedule: Vec<bool> = (0..96).map(|_| rng.range(3) == 0).collect();
        let mut e = engine();
        let req = xs_request(k, 5, seed, temperature).with_n(3);
        let id = e.submit(req).map_err(|e| e.to_string())?;
        let resp =
            run_family_with_preemption(&mut e, id, |s| schedule.get(s).copied().unwrap_or(false));
        for (i, choice) in resp.choices.iter().enumerate() {
            if choice.content != want[i].text() {
                return Err(format!(
                    "preempted choice {i} {:?} != independent run {:?} (k={k})",
                    choice.content,
                    want[i].text()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn fork_composes_with_grammar_fast_forward_and_speculation() {
    // The full stack at once: n=2 fan-out, JSON-schema grammar with
    // fast-forward, a divergent draft model, and eviction every other
    // step. Each choice still matches its independent seeded run on the
    // same speculative configuration, and no pages leak.
    let spec_cfg = || {
        let mut cfg = EngineConfig::reference(&[MODEL]);
        cfg.draft_model = Some(DRAFT.to_string());
        cfg.enable_fast_forward = true;
        cfg
    };
    let schema = r#"{
        "type": "object",
        "properties": {"ok": {"type": "boolean"}, "n": {"type": "integer"}},
        "required": ["ok", "n"]
    }"#;
    let seed = 0xF0_5EED;
    let mk = |s: u64| {
        let mut r = ChatCompletionRequest::new(MODEL).user("emit json");
        r.max_tokens = 100;
        r.sampling.temperature = 0.8;
        r.sampling.seed = Some(s);
        // '}' nudge closes the integer so derivations finish early.
        r.sampling.logit_bias.insert(8 + b'}' as u32, 5.0);
        r.response_format = ResponseFormat::JsonSchema(parse(schema).unwrap());
        r
    };

    let mut want = Vec::new();
    for i in 0..2 {
        let solo =
            MLCEngine::new(&spec_cfg()).unwrap().chat_completion(mk(branch_seed(seed, i))).unwrap();
        assert!(parse(solo.text()).is_ok(), "baseline must satisfy the schema");
        want.push(solo);
    }

    let mut e = MLCEngine::new(&spec_cfg()).unwrap();
    let idle_pages = model_stat(&e, "available_pages");
    let id = e.submit(mk(seed).with_n(2)).unwrap();
    let resp = run_family_with_preemption(&mut e, id, |s| s % 2 == 0);
    for (i, choice) in resp.choices.iter().enumerate() {
        assert_eq!(choice.content, want[i].text(), "spec+grammar choice {i} diverged");
        assert!(parse(&choice.content).is_ok(), "choice {i} broke the schema");
    }
    assert_eq!(stat_i64(&e, "forks"), 1);
    assert!(stat_i64(&e, "preemptions") > 0, "schedule never actually evicted");
    assert!(!e.has_work());
    assert_eq!(model_stat(&e, "available_pages"), idle_pages, "pages leaked");
}

// -- (d) streamed families partition by choice index -------------------------

#[test]
fn streamed_family_chunks_carry_choice_indices() {
    let n = 3;
    let mut req = xs_request(10, 5, 99, 0.9).with_n(n);
    req.stream = true;
    let mut e = engine();
    let id = e.submit(req).unwrap();

    let mut texts = vec![String::new(); n];
    let mut finishes = vec![0usize; n];
    let mut usage_chunks = 0;
    let mut done = None;
    for _ in 0..200 {
        e.step().unwrap();
        for ev in e.poll_events() {
            match ev {
                EngineEvent::Chunk(rid, c) => {
                    assert_eq!(rid, id);
                    assert!(c.index < n, "chunk index {} out of range", c.index);
                    texts[c.index].push_str(&c.delta);
                    if c.finish_reason.is_some() {
                        finishes[c.index] += 1;
                    }
                    if c.usage.is_some() {
                        usage_chunks += 1;
                    }
                }
                EngineEvent::Done(rid, resp) => {
                    assert_eq!(rid, id);
                    done = Some(resp);
                }
                EngineEvent::Error(_, e) => panic!("stream failed: {e}"),
            }
        }
        if !e.has_work() {
            break;
        }
    }
    let done = done.expect("family never completed");

    // Every choice streamed to its own index lane: one finish chunk per
    // branch, aggregate usage on exactly one (the last) chunk, and the
    // concatenated deltas reproduce each final choice byte for byte.
    assert_eq!(finishes, vec![1; n], "each choice needs exactly one finish chunk");
    assert_eq!(usage_chunks, 1, "aggregate usage rides exactly one chunk");
    assert_eq!(done.choices.len(), n);
    for (i, choice) in done.choices.iter().enumerate() {
        assert_eq!(choice.index, i);
        assert_eq!(texts[i], choice.content, "streamed bytes != choice {i}");
    }
}

// -- (e) abort hygiene + prefix-cache session reuse --------------------------

#[test]
fn abort_resolves_the_whole_family_without_leaking_pages() {
    let mut e = engine();
    let idle_pages = model_stat(&e, "available_pages");
    let id = e.submit(xs_request(40, 40, 1, 0.7).with_n(3)).unwrap();
    // Reach steady-state decode: all three branches running.
    for _ in 0..40 {
        e.step().unwrap();
        if model_stat(&e, "running") == 3 {
            break;
        }
    }
    assert_eq!(model_stat(&e, "running"), 3, "family never fanned out");

    e.abort(id);
    e.abort(999_999); // unknown ids are a no-op
    e.run_to_completion().unwrap();
    let terminal = e
        .poll_events()
        .into_iter()
        .filter(|ev| {
            matches!(ev, EngineEvent::Done(rid, _) | EngineEvent::Error(rid, _) if *rid == id)
        })
        .count();
    assert_eq!(terminal, 1, "an aborted family must resolve exactly once");
    assert_eq!(model_stat(&e, "available_pages"), idle_pages, "abort leaked pages");

    // The pool is genuinely reusable afterwards.
    let resp = e.chat_completion(xs_request(40, 2, 1, 0.0).with_n(2)).unwrap();
    assert_eq!(resp.choices.len(), 2);
}

#[test]
fn family_completion_seeds_the_prefix_cache_for_session_reuse() {
    // After a family finishes, its full prompt pages land in the prefix
    // cache exactly once (refcounts drained in any free order), so a
    // follow-up request over the same prompt prefills O(new tokens).
    let mut e = engine();
    let first = e.chat_completion(xs_request(40, 4, 3, 0.0).with_n(2)).unwrap();
    assert_eq!(stat_i64(&e, "prefill_cached_tokens_skipped"), 0);

    let again = e.chat_completion(xs_request(40, 4, 3, 0.0)).unwrap();
    assert_eq!(again.text(), first.choices[0].content, "warm rerun diverged");
    // 44 prompt tokens = 5 full pages the cache can keep (40 tokens).
    assert!(
        stat_i64(&e, "prefill_cached_tokens_skipped") >= 32,
        "follow-up session recomputed the shared prompt: only {} tokens skipped",
        stat_i64(&e, "prefill_cached_tokens_skipped")
    );
}

// -- validation ---------------------------------------------------------------

#[test]
fn submit_rejects_unservable_n() {
    let mut e = engine();
    let err = e.submit(xs_request(4, 2, 0, 0.0).with_n(0)).unwrap_err();
    assert_eq!(err.status, 400);
    assert!(err.message.contains("'n'"), "{}", err.message);
    let err = e.submit(xs_request(4, 2, 0, 0.0).with_n(10_000)).unwrap_err();
    assert_eq!(err.status, 400);
    assert!(err.message.contains("max decode batch"), "{}", err.message);
}
