//! Fault-tolerance tier: a seeded [`FaultPlan`] over the deterministic
//! reference backend must be *invisible* in the output and *exactly*
//! visible in the counters.
//!
//! Load-bearing properties:
//!   (a) transient faults retry to byte-identical output; a transient
//!       that outlives the retry budget escalates to a device reset and
//!       the output is STILL byte-identical;
//!   (b) a NaN logits row fails exactly the implicated request with a
//!       structured `data_plane_error` — survivors are untouched;
//!   (c) device loss preempts every resident, resets the KV pool, and
//!       recompute-on-resume reproduces every stream bit for bit —
//!       including under grammar fast-forward + speculative decoding +
//!       concurrent manual preemption;
//!   (d) `step()` never returns `Err` for a recoverable fault;
//!   (e) deadlines and drain produce structured `timeout_error` /
//!       `draining` failures and exact counters, never hangs.

use std::collections::HashMap;
use webllm::api::{ApiError, ChatCompletionRequest, ChatCompletionResponse, ResponseFormat};
use webllm::coordinator::{EngineConfig, EngineEvent, MLCEngine, RequestId};
use webllm::json::parse;
use webllm::runtime::{FaultKind, FaultPlan};
use webllm::testutil::ban_reference_eos as ban_eos;

const MODEL: &str = "tiny-ref";
/// Divergent drafter (different depth/pool) so rejection paths run.
const DRAFT: &str = "tiny-ref-b";

fn engine() -> MLCEngine {
    MLCEngine::new(&EngineConfig::reference(&[MODEL])).expect("engine")
}

fn faulty_engine(plan: FaultPlan) -> MLCEngine {
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.fault_plan = Some(plan);
    MLCEngine::new(&cfg).expect("engine")
}

/// Greedy request over `'x' * k` (k + 4 prompt tokens, no merges).
fn xs_request(k: usize, max_tokens: usize) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new(MODEL).user("x".repeat(k));
    r.max_tokens = max_tokens;
    r.sampling.temperature = 0.0;
    ban_eos(&mut r);
    r
}

/// Counter from the `"faults"` section of `stats_json`.
fn fault_stat(engine: &MLCEngine, key: &str) -> i64 {
    engine
        .stats_json()
        .get("faults")
        .unwrap_or_else(|| panic!("stats_json has no 'faults' section"))
        .get(key)
        .unwrap_or_else(|| panic!("no fault counter '{key}'"))
        .as_i64()
        .unwrap()
}

/// Drive to idle, asserting `step()` stays `Ok` the whole way (property
/// (d)); collect terminal events per request. Bounded so a recovery bug
/// fails loudly instead of hanging the suite.
fn drive(
    engine: &mut MLCEngine,
) -> (HashMap<RequestId, ChatCompletionResponse>, HashMap<RequestId, ApiError>) {
    let mut done = HashMap::new();
    let mut failed = HashMap::new();
    for _ in 0..500 {
        engine.step().expect("step() must absorb recoverable faults");
        for ev in engine.poll_events() {
            match ev {
                EngineEvent::Done(id, resp) => {
                    done.insert(id, resp);
                }
                EngineEvent::Error(id, e) => {
                    failed.insert(id, e);
                }
                _ => {}
            }
        }
        if !engine.has_work() {
            return (done, failed);
        }
    }
    panic!("engine did not go idle within 500 steps");
}

/// Fault-free terminal texts for the same submission order, keyed by the
/// request ids a fresh engine hands out (ids restart at 1 per engine, so
/// they line up between baseline and faulted runs).
fn baseline_texts(reqs: &[ChatCompletionRequest]) -> HashMap<RequestId, String> {
    let mut e = engine();
    for r in reqs {
        e.submit(r.clone()).unwrap();
    }
    let (done, failed) = drive(&mut e);
    assert!(failed.is_empty(), "fault-free baseline failed: {failed:?}");
    done.into_iter().map(|(id, r)| (id, r.text().to_string())).collect()
}

// -- (a) transient faults ------------------------------------------------------

#[test]
fn scheduled_transients_retry_to_identical_output() {
    let baseline = engine().chat_completion(xs_request(8, 6)).unwrap();

    // Ops for one 12-token prompt: op 0 = prefill chunk, ops 1+ = decodes.
    // Transients at ops 1 and 2: the op-1 call fails, its retry consumes
    // op 2 and fails again, the next retry (op 3) succeeds.
    let mut e = faulty_engine(FaultPlan::at(vec![
        (1, FaultKind::Transient),
        (2, FaultKind::Transient),
    ]));
    let id = e.submit(xs_request(8, 6)).unwrap();
    let (done, failed) = drive(&mut e);

    assert!(failed.is_empty(), "transients must be invisible: {failed:?}");
    assert_eq!(done[&id].text(), baseline.text());
    assert_eq!(done[&id].usage.completion_tokens, 6);
    assert_eq!(fault_stat(&e, "faults_injected"), 2, "both scheduled transients observed");
    assert_eq!(fault_stat(&e, "transient_retries"), 2);
    assert_eq!(fault_stat(&e, "device_resets"), 0, "retries alone must not reset");
    assert_eq!(fault_stat(&e, "requests_failed"), 0);
}

#[test]
fn transient_exhaustion_escalates_to_device_reset_output_unchanged() {
    let baseline = engine().chat_completion(xs_request(8, 8)).unwrap();

    // Four back-to-back scheduled transients: one engine call observes
    // ops 1..=4 (attempt 0 plus MAX_TRANSIENT_RETRIES = 3 retries), gives
    // up, and escalates to the device-loss path — preempt, reset,
    // recompute. The stream must still be byte-identical.
    let mut e = faulty_engine(FaultPlan::at(vec![
        (1, FaultKind::Transient),
        (2, FaultKind::Transient),
        (3, FaultKind::Transient),
        (4, FaultKind::Transient),
    ]));
    let id = e.submit(xs_request(8, 8)).unwrap();
    let (done, failed) = drive(&mut e);

    assert!(failed.is_empty(), "escalation must recover, not fail: {failed:?}");
    assert_eq!(done[&id].text(), baseline.text(), "reset+recompute changed the stream");
    assert_eq!(fault_stat(&e, "faults_injected"), 4);
    assert_eq!(fault_stat(&e, "transient_retries"), 3, "retry budget is 3");
    assert_eq!(fault_stat(&e, "device_resets"), 1, "4th observation escalates");
    assert_eq!(fault_stat(&e, "requests_failed"), 0);
}

// -- (b) data-plane isolation --------------------------------------------------

#[test]
fn nan_row_fails_exactly_one_request_and_survivors_are_byte_identical() {
    let reqs = [xs_request(8, 24), xs_request(16, 24)];
    let baseline = baseline_texts(&reqs);

    // Op 10 is deep in steady-state decode with both sequences live;
    // NanRow(0) poisons the first live row only.
    let mut e = faulty_engine(FaultPlan::at(vec![(10, FaultKind::NanRow(0))]));
    for r in &reqs {
        e.submit(r.clone()).unwrap();
    }
    let (done, failed) = drive(&mut e);

    assert_eq!(failed.len(), 1, "exactly one request fails per poisoned row");
    let (victim, err) = failed.iter().next().unwrap();
    assert_eq!(err.kind, "data_plane_error", "{err}");
    assert_eq!(err.status, 500);
    assert!(err.message.contains("non-finite"), "{err}");
    assert_eq!(done.len(), 1);
    for (id, resp) in &done {
        assert_ne!(id, victim);
        assert_eq!(resp.text(), baseline[id], "survivor's stream was disturbed");
    }
    assert_eq!(fault_stat(&e, "faults_injected"), 1);
    assert_eq!(fault_stat(&e, "requests_failed"), 1);
    assert_eq!(fault_stat(&e, "device_resets"), 0, "data-plane faults must not reset");
}

// -- (c) device loss -----------------------------------------------------------

#[test]
fn device_loss_preempts_everyone_and_every_stream_resumes_identically() {
    let reqs = [xs_request(8, 12), xs_request(12, 12), xs_request(16, 12)];
    let baseline = baseline_texts(&reqs);

    let mut e = faulty_engine(FaultPlan::at(vec![(9, FaultKind::DeviceLost)]));
    for r in &reqs {
        e.submit(r.clone()).unwrap();
    }
    let (done, failed) = drive(&mut e);

    assert!(failed.is_empty(), "device loss must fail no one: {failed:?}");
    assert_eq!(done.len(), 3);
    for (id, resp) in &done {
        assert_eq!(resp.text(), baseline[id], "request {id} diverged across the reset");
        assert_eq!(resp.usage.completion_tokens, 12);
    }
    assert_eq!(fault_stat(&e, "faults_injected"), 1, "sticky repeats are not re-counted");
    assert_eq!(fault_stat(&e, "device_resets"), 1);
    assert!(
        e.stats_json().get("preemptions").unwrap().as_i64().unwrap() >= 1,
        "reset must go through the preemption machinery"
    );
}

#[test]
fn device_loss_composes_with_speculation_grammar_and_manual_preemption() {
    let spec_cfg = |plan: Option<FaultPlan>| {
        let mut cfg = EngineConfig::reference(&[MODEL]);
        cfg.draft_model = Some(DRAFT.to_string());
        cfg.enable_fast_forward = true;
        cfg.fault_plan = plan;
        cfg
    };
    let schema = r#"{
        "type": "object",
        "properties": {"ok": {"type": "boolean"}, "n": {"type": "integer"}},
        "required": ["ok", "n"]
    }"#;
    let mk = |k: usize| {
        let mut r = ChatCompletionRequest::new(MODEL).user(format!("emit json {}", "x".repeat(k)));
        r.max_tokens = 100;
        r.sampling.temperature = 0.0;
        r.sampling.logit_bias.insert(8 + b'}' as u32, 5.0);
        r.response_format = ResponseFormat::JsonSchema(parse(schema).unwrap());
        r
    };

    let baseline = MLCEngine::new(&spec_cfg(None)).unwrap().chat_completion(mk(60)).unwrap();
    assert!(parse(baseline.text()).is_ok(), "baseline must satisfy the schema");

    // Device loss mid-prefill of the 68-token prompt (op 2), a transient
    // during the speculation rounds (op 5), and a manual eviction every
    // third step on top: three output-invariant mechanisms stacked.
    let plan = FaultPlan::at(vec![(2, FaultKind::DeviceLost), (5, FaultKind::Transient)]);
    let mut e = MLCEngine::new(&spec_cfg(Some(plan))).unwrap();
    let id = e.submit(mk(60)).unwrap();
    let mut resp = None;
    for step in 0..500 {
        if step % 3 == 0 {
            e.preempt(id);
        }
        e.step().expect("step() must absorb recoverable faults");
        for ev in e.poll_events() {
            match ev {
                EngineEvent::Done(_, r) => resp = Some(r),
                EngineEvent::Error(_, err) => panic!("request failed: {err}"),
                _ => {}
            }
        }
        if !e.has_work() {
            break;
        }
    }
    let resp = resp.expect("request did not complete");
    assert_eq!(resp.text(), baseline.text(), "spec+grammar+preempt+faults changed output");
    assert_eq!(fault_stat(&e, "device_resets"), 1);
    assert_eq!(fault_stat(&e, "faults_injected"), 2);
    assert_eq!(fault_stat(&e, "requests_failed"), 0);
}

// -- mixed-schedule acceptance -------------------------------------------------

#[test]
fn mixed_schedule_counters_match_exactly_and_survivors_are_identical() {
    let reqs = [xs_request(8, 16), xs_request(12, 16), xs_request(16, 16)];
    let baseline = baseline_texts(&reqs);

    // One transient (retries), one NaN row (fails one request), one
    // device loss (resets, everyone else resumes).
    let plan = FaultPlan::at(vec![
        (4, FaultKind::Transient),
        (9, FaultKind::NanRow(0)),
        (15, FaultKind::DeviceLost),
    ]);
    let mut e = faulty_engine(plan);
    for r in &reqs {
        e.submit(r.clone()).unwrap();
    }
    let (done, failed) = drive(&mut e);

    assert_eq!(failed.len(), 1, "exactly the NaN-row victim fails: {failed:?}");
    assert_eq!(failed.values().next().unwrap().kind, "data_plane_error");
    assert_eq!(done.len(), 2);
    for (id, resp) in &done {
        assert_eq!(resp.text(), baseline[id], "survivor {id} diverged");
    }
    assert_eq!(fault_stat(&e, "faults_injected"), 3, "schedule observed exactly");
    assert_eq!(fault_stat(&e, "transient_retries"), 1);
    assert_eq!(fault_stat(&e, "device_resets"), 1);
    assert_eq!(fault_stat(&e, "requests_failed"), 1);
    assert_eq!(fault_stat(&e, "requests_timed_out"), 0);
}

#[test]
fn seeded_chaos_never_wedges_the_engine() {
    // A randomized (but reproducible) schedule: transients, NaN rows,
    // short stalls at 15% of ops. Whatever lands, every request reaches a
    // terminal state, `step()` stays Ok, and the engine goes idle.
    let mut cfg = EngineConfig::reference(&[MODEL]);
    // `.then` pins one engine-visible fault so the injected-counter
    // assertion below can't depend on where the seeded rolls land.
    cfg.fault_plan = Some(FaultPlan::seeded(0xC0FFEE, 60, 15).then(1, FaultKind::Transient));
    let mut e = MLCEngine::new(&cfg).unwrap();
    let n = 3;
    for k in [6, 10, 14] {
        e.submit(xs_request(k, 8)).unwrap();
    }
    let (done, failed) = drive(&mut e);
    assert_eq!(done.len() + failed.len(), n, "every request must terminate");
    for err in failed.values() {
        assert_eq!(err.kind, "data_plane_error", "only NaN rows may fail requests: {err}");
    }
    assert!(!e.has_work());
    assert!(fault_stat(&e, "faults_injected") >= 1, "15% over 60 ops scheduled nothing?");
}

// -- watchdog ------------------------------------------------------------------

#[test]
fn stalled_step_trips_the_watchdog_without_changing_output() {
    let baseline = engine().chat_completion(xs_request(8, 5)).unwrap();

    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.watchdog_step_ms = 5;
    cfg.fault_plan = Some(FaultPlan::at(vec![(1, FaultKind::StallMs(20))]));
    let mut e = MLCEngine::new(&cfg).unwrap();
    let id = e.submit(xs_request(8, 5)).unwrap();
    let (done, failed) = drive(&mut e);

    assert!(failed.is_empty(), "a stall is latency, not an error: {failed:?}");
    assert_eq!(done[&id].text(), baseline.text());
    assert!(fault_stat(&e, "watchdog_stalls") >= 1, "20ms stall above a 5ms watchdog");
}

// -- deadlines -----------------------------------------------------------------

#[test]
fn expired_deadline_fails_with_structured_timeout() {
    let mut e = engine();
    // deadline_ms = 0: expired the moment it was admitted to the queue.
    let id = e.submit(xs_request(8, 4).with_deadline_ms(0)).unwrap();
    let ok = e.submit(xs_request(8, 4)).unwrap();
    let (done, failed) = drive(&mut e);

    let err = &failed[&id];
    assert_eq!(err.status, 408, "{err}");
    assert_eq!(err.kind, "timeout_error", "{err}");
    assert_eq!(fault_stat(&e, "requests_timed_out"), 1);
    assert_eq!(fault_stat(&e, "requests_failed"), 0, "timeouts are counted separately");
    assert!(done.contains_key(&ok), "the undeadlined request must be untouched");
}

#[test]
fn engine_default_timeout_applies_when_request_sets_none() {
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.request_timeout_ms = Some(0); // --request-timeout 0: everything expires
    let mut e = MLCEngine::new(&cfg).unwrap();
    let id = e.submit(xs_request(8, 4)).unwrap();
    let generous = e.submit(xs_request(8, 4).with_deadline_ms(60_000)).unwrap();
    let (done, failed) = drive(&mut e);

    assert_eq!(failed[&id].kind, "timeout_error");
    assert!(done.contains_key(&generous), "per-request deadline overrides the default");
    assert_eq!(fault_stat(&e, "requests_timed_out"), 1);
}

#[test]
fn deadline_expires_mid_decode_and_frees_the_slot() {
    let mut e = engine();
    let id = e.submit(xs_request(8, 400).with_deadline_ms(30)).unwrap();
    // Reach steady-state decode, then let the deadline lapse.
    for _ in 0..3 {
        e.step().unwrap();
    }
    std::thread::sleep(std::time::Duration::from_millis(40));
    let (done, failed) = drive(&mut e);

    assert!(done.is_empty());
    let err = &failed[&id];
    assert_eq!(err.kind, "timeout_error", "{err}");
    assert!(err.message.contains("mid-decode") || err.message.contains("deadline"), "{err}");
    assert_eq!(fault_stat(&e, "requests_timed_out"), 1);
    assert!(!e.has_work(), "timed-out request must release its residency");
}

// -- graceful drain ------------------------------------------------------------

#[test]
fn drain_finishes_residents_rejects_new_and_reports_drained() {
    let mut e = engine();
    let a = e.submit(xs_request(8, 4)).unwrap();
    let b = e.submit(xs_request(12, 4)).unwrap();
    for _ in 0..2 {
        e.step().unwrap();
    }

    e.drain(None);
    assert!(e.is_draining());
    assert!(!e.drained(), "residents still in flight");
    let err = e.submit(xs_request(8, 4)).unwrap_err();
    assert_eq!(err.status, 503, "{err}");
    assert_eq!(err.kind, "draining", "{err}");
    assert_eq!(fault_stat(&e, "drain_rejected"), 1);

    let (done, failed) = drive(&mut e);
    assert!(failed.is_empty(), "an unbounded drain fails no resident: {failed:?}");
    assert!(done.contains_key(&a) && done.contains_key(&b));
    assert!(e.drained());
    assert_eq!(fault_stat(&e, "drain_completed"), 2);
    assert_eq!(fault_stat(&e, "drain_failed"), 0);
    // `stats_json` advertises the lifecycle state for ops tooling.
    assert_eq!(e.stats_json().get("draining").unwrap().as_bool(), Some(true));
}

#[test]
fn drain_deadline_bounds_shutdown_by_failing_stragglers() {
    let mut e = engine();
    for k in [8, 12] {
        e.submit(xs_request(k, 64)).unwrap();
    }
    for _ in 0..3 {
        e.step().unwrap();
    }

    // Zero grace: the next step must evict everyone still resident.
    e.drain(Some(0));
    let (done, failed) = drive(&mut e);

    assert!(done.is_empty(), "64-token requests cannot finish in zero grace");
    assert_eq!(failed.len(), 2);
    for err in failed.values() {
        assert_eq!(err.status, 503, "{err}");
        assert_eq!(err.kind, "draining", "{err}");
    }
    assert_eq!(fault_stat(&e, "drain_failed"), 2);
    assert!(e.drained());
    assert!(!e.has_work(), "drained engine must hold no residents");
}

#[test]
fn drain_completes_through_the_worker_wire_protocol() {
    // End-to-end through ServiceWorkerMLCEngine: Drain posts on the wire,
    // Drained comes back exactly once, and completions beat the ack.
    use webllm::coordinator::ServiceWorkerMLCEngine;
    let mut fe = ServiceWorkerMLCEngine::create(EngineConfig::reference(&[MODEL])).unwrap();
    let mut req = ChatCompletionRequest::new(MODEL).user("x".repeat(8));
    req.max_tokens = 3;
    req.sampling.temperature = 0.0;
    ban_eos(&mut req);
    let id = fe.submit(req.clone()).unwrap();
    fe.drain(None).unwrap();
    fe.wait_drained().unwrap();
    // The resident finished before the ack; its Done is buffered, not lost.
    let mut saw_done = false;
    for _ in 0..50 {
        match fe.poll(std::time::Duration::from_millis(500)).unwrap() {
            webllm::coordinator::FromWorker::Done { id: did, .. } => {
                assert_eq!(did, id);
                saw_done = true;
                break;
            }
            _ => {}
        }
    }
    assert!(saw_done, "drain dropped a completion");
    // Post-drain submissions are turned away with the structured error.
    let err = fe.chat_completion(req).unwrap_err();
    assert_eq!(err.kind, "draining", "{err}");
}
