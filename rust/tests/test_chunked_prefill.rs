//! Chunked, prefix-aware prefill: scheduler-level integration tests on
//! the deterministic reference backend (no artifacts, runs everywhere).
//!
//! The load-bearing property: the chunk budget, the number of chunks a
//! prompt is sliced into, and the prefix-cache skip are all *scheduling*
//! decisions — the token stream they produce must be identical to
//! whole-prompt prefill, bit for bit. The reference backend's
//! hash-of-prefix logits make that checkable by exact string equality.

use webllm::api::{ChatCompletionRequest, FinishReason};
use webllm::coordinator::{EngineConfig, EngineEvent, MLCEngine, ServiceWorkerMLCEngine};
use webllm::testutil::prop::Runner;
use webllm::testutil::{ban_reference_eos as ban_eos, ban_reference_invisible as ban_invisible};

const MODEL: &str = "tiny-ref";
/// Reference-model geometry (pinned by `models::reference` tests).
const MAX_CHUNK: usize = 64;
const PAGE: usize = 8;

fn engine_with_budget(budget: usize) -> MLCEngine {
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.prefill_token_budget = budget;
    // These tests pin exact chunk counts to the configured budget; the
    // adaptive policy would rescale it with the live decode batch.
    cfg.adaptive_prefill = false;
    MLCEngine::new(&cfg).expect("engine")
}

fn engine() -> MLCEngine {
    MLCEngine::new(&EngineConfig::reference(&[MODEL])).expect("engine")
}

/// Greedy request whose rendered prompt is `'x' * k` plus the 4 template
/// specials — 'x' has no merges in the reference vocab, so the prompt is
/// exactly `k + 4` tokens.
fn xs_request(k: usize, max_tokens: usize) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new(MODEL).user("x".repeat(k));
    r.max_tokens = max_tokens;
    r.sampling.temperature = 0.0;
    ban_eos(&mut r);
    r
}

fn stat_i64(engine: &MLCEngine, key: &str) -> i64 {
    engine.stats_json().get(key).unwrap().as_i64().unwrap()
}

// -- regression: prompts longer than the largest compiled chunk -------------

#[test]
fn prompt_of_max_chunk_plus_page_size_completes() {
    // Exactly max_prefill_chunk() + page_size prompt tokens — the shape
    // `submit` used to reject outright (engine.rs:356 pre-chunking).
    let mut engine = engine();
    let want_prompt = MAX_CHUNK + PAGE; // 72
    let resp = engine.chat_completion(xs_request(want_prompt - 4, 6)).unwrap();
    assert_eq!(resp.usage.prompt_tokens, want_prompt, "test prompt arithmetic drifted");
    assert_eq!(resp.usage.completion_tokens, 6);
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Length);
    // Sliced as 64 + 8 under the default (menu-clamped) budget.
    assert_eq!(stat_i64(&engine, "prefill_chunks"), 2);
    assert_eq!(stat_i64(&engine, "prefill_tokens"), want_prompt as i64);
    assert_eq!(stat_i64(&engine, "prefill_cached_tokens_skipped"), 0);
}

#[test]
fn long_prompt_works_over_the_worker_boundary() {
    // The submit-time rejection also used to fire on the worker path.
    let mut fe = ServiceWorkerMLCEngine::create(EngineConfig::reference(&[MODEL])).unwrap();
    let resp = fe.chat_completion(xs_request(80, 4)).unwrap();
    assert_eq!(resp.usage.prompt_tokens, 84);
    assert_eq!(resp.usage.completion_tokens, 4);

    // And the direct engine agrees token-for-token.
    let direct = engine().chat_completion(xs_request(80, 4)).unwrap();
    assert_eq!(resp.text(), direct.text());
}

// -- the equivalence property -----------------------------------------------

#[test]
fn prop_chunked_prefill_equals_whole_prompt_token_for_token() {
    // Any chunk budget, warm or cold prefix cache: identical output to
    // the max-budget cold baseline.
    const ALPHABET: &[u8] = b"abcdefgh xyz,.qrstuv";
    Runner::new("chunked_prefill_equivalence", 6).run(|rng| {
        let k = rng.range(91); // prompt: k + 4 tokens, up to 94 < context
        let content: String = (0..k)
            .map(|_| ALPHABET[rng.range(ALPHABET.len())] as char)
            .collect();
        let seed = rng.u64();
        let temperature = 0.2 + rng.f64() as f32;
        let mk = || {
            let mut r = ChatCompletionRequest::new(MODEL).user(content.clone());
            r.max_tokens = 6;
            r.sampling.seed = Some(seed);
            r.sampling.temperature = temperature;
            r
        };

        let baseline = engine_with_budget(usize::MAX)
            .chat_completion(mk())
            .map_err(|e| e.to_string())?;

        for budget in [1usize, 5, 17, 32, 1000] {
            let mut e = engine_with_budget(budget);
            // Cold: fresh engine, empty prefix cache.
            let cold = e.chat_completion(mk()).map_err(|e| e.to_string())?;
            if cold.text() != baseline.text() {
                return Err(format!(
                    "budget {budget} cold: {:?} != baseline {:?} (prompt {k} chars)",
                    cold.text(),
                    baseline.text()
                ));
            }
            // Warm: same engine again — leading pages now come from the
            // prefix cache and are skipped, not recomputed.
            let skipped_before = stat_i64(&e, "prefill_cached_tokens_skipped");
            let warm = e.chat_completion(mk()).map_err(|e| e.to_string())?;
            if warm.text() != baseline.text() {
                return Err(format!(
                    "budget {budget} warm: {:?} != baseline {:?} (prompt {k} chars)",
                    warm.text(),
                    baseline.text()
                ));
            }
            let skipped = stat_i64(&e, "prefill_cached_tokens_skipped") - skipped_before;
            let full_pages = (cold.usage.prompt_tokens / PAGE) as i64;
            if full_pages > 0 && skipped == 0 {
                return Err(format!(
                    "budget {budget}: warm rerun of a {}-token prompt skipped nothing",
                    cold.usage.prompt_tokens
                ));
            }
        }
        Ok(())
    });
}

// -- prefix-cache skip accounting -------------------------------------------

#[test]
fn fully_cached_prompt_recomputes_only_the_final_token() {
    // The acceptance criterion: a warm-prefix prompt costs O(uncached
    // suffix). A prompt of exactly 4 pages, repeated, is fully cached —
    // only the final token (whose logits seed the first sampled token)
    // is recomputed.
    let mut engine = engine();
    let prompt_tokens = 4 * PAGE; // 32 = 28 'x's + 4 specials
    let a = engine.chat_completion(xs_request(prompt_tokens - 4, 4)).unwrap();
    assert_eq!(a.usage.prompt_tokens, prompt_tokens);
    assert_eq!(stat_i64(&engine, "prefill_tokens"), 32);
    assert_eq!(stat_i64(&engine, "prefill_cached_tokens_skipped"), 0);

    let b = engine.chat_completion(xs_request(prompt_tokens - 4, 4)).unwrap();
    assert_eq!(a.text(), b.text(), "prefix skip must not change the output");
    // Request B prefilled exactly one position: 32 total minus 31 skipped.
    assert_eq!(stat_i64(&engine, "prefill_cached_tokens_skipped"), 31);
    assert_eq!(stat_i64(&engine, "prefill_tokens"), 32 + 1);
    assert_eq!(stat_i64(&engine, "prefill_chunks"), 2);

    // The per-model prefix cache agrees it served the pages.
    let stats = engine.stats_json();
    let model = stats.get("models").unwrap().get(MODEL).unwrap();
    assert!(model.get("prefix_cache_hits").unwrap().as_i64().unwrap() >= 4);
}

#[test]
fn partially_cached_prompt_prefills_only_the_suffix() {
    let mut engine = engine();
    // First request: 2 full pages + 3 tokens (content 15 'x's => 19 tokens).
    engine.chat_completion(xs_request(15, 4)).unwrap();
    let base_tokens = stat_i64(&engine, "prefill_tokens");
    assert_eq!(base_tokens, 19);

    // Second request shares the first 2 pages (16 tokens), then diverges.
    let mut r = ChatCompletionRequest::new(MODEL).user(format!("{}yyyyyyyy", "x".repeat(15)));
    r.max_tokens = 4;
    r.sampling.temperature = 0.0;
    ban_eos(&mut r);
    let resp = engine.chat_completion(r).unwrap();
    assert_eq!(resp.usage.prompt_tokens, 27);
    assert_eq!(stat_i64(&engine, "prefill_cached_tokens_skipped"), 16);
    assert_eq!(stat_i64(&engine, "prefill_tokens"), base_tokens + (27 - 16));
}

// -- decode/prefill interleaving --------------------------------------------

#[test]
fn decode_progresses_while_a_long_prompt_prefills() {
    // The whole point of chunking: admitting a long prompt no longer
    // stalls running sequences for its entire prefill.
    let mut engine = engine_with_budget(16);

    // A: streaming, guaranteed-visible tokens, long enough to outlive
    // B's prefill; short prompt (6 tokens) so A itself takes one chunk.
    let mut a = ChatCompletionRequest::new(MODEL).user("hi");
    a.max_tokens = 30;
    a.sampling.temperature = 0.0;
    a.stream = true;
    ban_invisible(&mut a);
    let a_id = engine.submit(a).unwrap();
    engine.step().unwrap(); // A prefills (1 chunk) and starts decoding
    engine.poll_events();

    // B: 72-token prompt => 5 chunks of 16/16/16/16/8 at budget 16.
    let b_id = engine.submit(xs_request(68, 4)).unwrap();
    engine.step().unwrap(); // B chunk 1 + A decode, co-scheduled
    let stats = engine.stats_json();
    let model = stats.get("models").unwrap().get(MODEL).unwrap();
    assert_eq!(
        model.get("prefilling").unwrap().as_i64(),
        Some(1),
        "B must still be mid-prefill after one step"
    );
    let a_chunks: usize = engine
        .poll_events()
        .iter()
        .filter(|ev| matches!(ev, EngineEvent::Chunk(rid, _) if *rid == a_id))
        .count();
    assert!(a_chunks >= 1, "A must receive tokens while B prefills");

    engine.run_to_completion().unwrap();
    let mut done = 0;
    for ev in engine.poll_events() {
        if let EngineEvent::Done(rid, resp) = ev {
            done += 1;
            if rid == b_id {
                assert_eq!(resp.usage.prompt_tokens, 72);
                assert_eq!(resp.usage.completion_tokens, 4);
            }
        }
    }
    assert_eq!(done, 2);

    // Stall accounting: every one of B's 5 chunks ran with A decoding.
    assert_eq!(stat_i64(&engine, "prefill_chunks"), 1 + 5);
    assert_eq!(stat_i64(&engine, "decode_stall_chunks"), 5);
    assert!(engine.stats_json().get("decode_stall_s").unwrap().as_f64().unwrap() >= 0.0);
}

// -- mid-prefill cancellation -----------------------------------------------

#[test]
fn abort_mid_prefill_resolves_and_leaves_engine_clean() {
    let mut engine = engine_with_budget(16);
    let baseline = engine_with_budget(16).chat_completion(xs_request(100, 4)).unwrap();

    // 104-token prompt => 7 chunks at budget 16; abort after 2.
    let id = engine.submit(xs_request(100, 4)).unwrap();
    engine.step().unwrap();
    engine.step().unwrap();
    engine.abort(id);
    engine.run_to_completion().unwrap();

    let mut saw = false;
    for ev in engine.poll_events() {
        if let EngineEvent::Done(rid, resp) = ev {
            assert_eq!(rid, id);
            assert_eq!(resp.choices[0].finish_reason, FinishReason::Abort);
            assert_eq!(resp.usage.completion_tokens, 0, "no token was ever sampled");
            assert_eq!(resp.text(), "");
            saw = true;
        }
    }
    assert!(saw, "aborted prefilling request must resolve");

    // The engine is intact — pages freed, scheduler idle.
    assert!(!engine.has_work());
    let stats = engine.stats_json();
    let model = stats.get("models").unwrap().get(MODEL).unwrap();
    assert_eq!(model.get("prefilling").unwrap().as_i64(), Some(0));
    assert_eq!(model.get("running").unwrap().as_i64(), Some(0));

    // And crucially: only pages whose chunks actually landed may have
    // been registered for prefix reuse — the page holding the abort
    // boundary was not. The same prompt resubmitted completes correctly
    // and identically to an untouched engine.
    let resp = engine.chat_completion(xs_request(100, 4)).unwrap();
    assert_eq!(resp.text(), baseline.text(), "abort must not poison the prefix cache");
    assert_eq!(resp.usage.completion_tokens, 4);
}

#[test]
fn abort_mid_prefill_does_not_disturb_decoding_neighbors() {
    let mut engine = engine_with_budget(16);
    let mut a = xs_request(4, 8);
    a.sampling.seed = Some(9);
    let baseline = engine_with_budget(16).chat_completion(a.clone()).unwrap();

    let a_id = engine.submit(a).unwrap();
    engine.step().unwrap(); // A decoding
    let b_id = engine.submit(xs_request(100, 4)).unwrap();
    engine.step().unwrap(); // B chunk 1
    engine.abort(b_id);
    engine.run_to_completion().unwrap();

    let mut a_text = None;
    for ev in engine.poll_events() {
        if let EngineEvent::Done(rid, resp) = ev {
            if rid == a_id {
                a_text = Some(resp.text().to_string());
            }
        }
    }
    assert_eq!(a_text.as_deref(), Some(baseline.text()), "neighbor output changed");
}

// -- budget knob ------------------------------------------------------------

#[test]
fn smaller_budgets_slice_into_more_chunks() {
    for (budget, want_chunks) in [(usize::MAX, 2), (32, 3), (16, 5), (1, 5)] {
        let mut e = engine_with_budget(budget);
        e.chat_completion(xs_request(68, 2)).unwrap(); // 72-token prompt
        assert_eq!(
            stat_i64(&e, "prefill_chunks"),
            want_chunks,
            "budget {budget}"
        );
        // Chunking never changes the total prefill work (cold cache).
        assert_eq!(stat_i64(&e, "prefill_tokens"), 72, "budget {budget}");
    }
}
