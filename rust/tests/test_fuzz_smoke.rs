//! Structure-aware fuzz smoke for the grammar front-ends.
//!
//! Deterministic (seeded), offline, and bounded — this is the in-tree
//! complement to the `cargo fuzz` targets under `fuzz/fuzz_targets/`,
//! which require a libfuzzer toolchain and are NOT built by CI. Each
//! smoke test mutates realistic seeds and asserts the invariant that
//! matters for an inference server taking untrusted schemas over HTTP:
//! the front-ends return `Ok` or a structured `GrammarError` — they
//! never panic, and anything they do accept yields a bounded, internally
//! consistent grammar the matcher can run.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::rc::Rc;

use webllm::grammar::{parse_ebnf, regex_to_grammar, schema_to_grammar, Grammar, GrammarMatcher};
use webllm::json::{parse, to_string, Value};
use webllm::testutil::prop::PropRng;
use webllm::testutil::schema_oracle;

const ITERS: usize = 400;
/// Generous ceiling over the compiler's own rule budget (20k) — a
/// mutated input that slips past `Err` must still come out bounded.
const MAX_RULES: usize = 25_000;
const MAX_DRIVE_BYTES: usize = 64;

/// Run `f`, mapping a panic to an error carrying the offending input.
fn no_panic<T>(what: &str, input: &str, f: impl FnOnce() -> T) -> T {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => v,
        Err(_) => panic!("{what} panicked on input: {input:?}"),
    }
}

/// Drive the matcher over random bytes; exercises the pushdown stacks
/// (and their dead-state pruning) on whatever grammar came out.
fn drive_matcher(rng: &mut PropRng, g: Grammar, input: &str) {
    if g.rules.len() > MAX_RULES {
        panic!("grammar from {input:?} exceeded rule budget: {}", g.rules.len());
    }
    if let Err(e) = g.validate() {
        panic!("invalid grammar from {input:?}: {e}");
    }
    let g = Rc::new(g);
    no_panic("matcher", input, || {
        let mut m = GrammarMatcher::new(g.clone());
        for _ in 0..MAX_DRIVE_BYTES {
            let b = match rng.range(4) {
                0 => b' ' + rng.range(95) as u8, // printable ASCII
                1 => *rng.choose(b"{}[]\",:0129ae-.tfn"),
                2 => rng.range(256) as u8, // arbitrary, incl. invalid UTF-8
                _ => b'"',
            };
            if !m.advance_bytes(&[b]) {
                break;
            }
            let _ = m.is_accepting();
        }
        let _ = m.fingerprint();
    });
}

/// Splice random bytes of `text` from a structure-biased pool.
fn mutate_text(rng: &mut PropRng, text: &str, pool: &[u8]) -> String {
    let mut bytes = text.as_bytes().to_vec();
    for _ in 0..1 + rng.range(4) {
        match rng.range(3) {
            0 if !bytes.is_empty() => {
                let i = rng.range(bytes.len());
                bytes[i] = *rng.choose(pool);
            }
            1 => {
                let i = rng.range(bytes.len() + 1);
                bytes.insert(i, *rng.choose(pool));
            }
            _ if !bytes.is_empty() => {
                bytes.remove(rng.range(bytes.len()));
            }
            _ => {}
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

#[test]
fn fuzz_smoke_ebnf() {
    let seeds = [
        r#"root ::= "a" | "b" root"#,
        r#"root ::= obj
obj ::= "{" ( pair ( "," pair )* )? "}"
pair ::= "\"" [a-z]+ "\"" ":" [0-9]+"#,
        r#"root ::= [a-zA-Z_] [a-zA-Z0-9_]*"#,
        r#"root ::= item{2,5}
item ::= [0-9] | "x""#,
        r#"root ::= ( "ab" | "cd" )+ [^\n]?"#,
    ];
    let pool = br#"rot:=|()[]{}*+?^-,"\ abz09_n"#;
    let mut rng = PropRng::new(0xEB0F);
    let mut parsed = 0usize;
    for i in 0..ITERS {
        let text = mutate_text(&mut rng, seeds[i % seeds.len()], pool);
        if let Ok(g) = no_panic("parse_ebnf", &text, || parse_ebnf(&text)) {
            parsed += 1;
            drive_matcher(&mut rng, g, &text);
        }
    }
    // The mutations are small, so a decent share must still parse —
    // otherwise the smoke test is only exercising the error path.
    assert!(parsed > ITERS / 20, "only {parsed}/{ITERS} mutants parsed");
    println!("fuzz_smoke_ebnf: {parsed}/{ITERS} mutants parsed and driven");
}

#[test]
fn fuzz_smoke_regex() {
    let seeds = [
        "^[A-Z]{2}-[0-9]{3}$",
        "^(ab|cd)+e?$",
        "^v[0-9]+\\.[0-9]+\\.[0-9]+$",
        "^[a-z]+(_[a-z]+)*$",
        "^a{2,4}b*c?$",
        "^x[0-9a-f]*$",
    ];
    let pool = br#"^$()[]{}|*+?\.-09azAZ,"#;
    let mut rng = PropRng::new(0x4E6E);
    let mut compiled = 0usize;
    for i in 0..ITERS {
        let pat = mutate_text(&mut rng, seeds[i % seeds.len()], pool);
        let res = no_panic("regex_to_grammar", &pat, || regex_to_grammar(&pat));
        // The independent oracle regex engine must also never panic on
        // the same pattern (it may reject it differently).
        no_panic("oracle regex", &pat, || {
            let _ = schema_oracle::regex_matches(&pat, "sample-090", false);
        });
        if let Ok(g) = res {
            compiled += 1;
            drive_matcher(&mut rng, g, &pat);
        }
    }
    assert!(compiled > ITERS / 20, "only {compiled}/{ITERS} mutants compiled");
    println!("fuzz_smoke_regex: {compiled}/{ITERS} mutants compiled and driven");
}

/// Pull every schema out of the conformance corpus as mutation seeds.
fn corpus_schemas() -> Vec<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/corpus");
    let mut files: Vec<_> = std::fs::read_dir(&dir)
        .expect("tests/corpus must exist")
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().map_or(false, |x| x == "json"))
        .collect();
    files.sort();
    let mut out = Vec::new();
    for p in files {
        let doc = parse(&std::fs::read_to_string(&p).expect("read")).expect("corpus json");
        for fx in doc.as_array().expect("fixture array") {
            if let Some(s) = fx.get("schema") {
                out.push(to_string(s));
            }
        }
    }
    assert!(out.len() >= 40, "too few corpus schemas: {}", out.len());
    out
}

#[test]
fn fuzz_smoke_schema() {
    let seeds = corpus_schemas();
    let pool = br#"{}[]",:0-9ae tfn\minmaxtypelng"#;
    let mut rng = PropRng::new(0x5C4E);
    let mut compiled = 0usize;
    for i in 0..ITERS {
        let seed = &seeds[i % seeds.len()];
        // Alternate byte-level splices with structural keyword grafts.
        let text = if rng.bool() {
            mutate_text(&mut rng, seed, pool)
        } else {
            match parse(seed) {
                Ok(mut v) => {
                    graft_keyword(&mut rng, &mut v);
                    to_string(&v)
                }
                Err(_) => seed.clone(),
            }
        };
        let Ok(schema) = parse(&text) else { continue };
        if let Ok(g) = no_panic("schema_to_grammar", &text, || schema_to_grammar(&schema)) {
            compiled += 1;
            drive_matcher(&mut rng, g, &text);
        }
        // The oracle must stay panic-free on the same mutant schema.
        no_panic("schema oracle", &text, || {
            let _ = schema_oracle::validate(&schema, &Value::Null);
        });
    }
    assert!(compiled > ITERS / 20, "only {compiled}/{ITERS} mutants compiled");
    println!("fuzz_smoke_schema: {compiled}/{ITERS} mutants compiled and driven");
}

/// Graft a random (often nonsensical) keyword somewhere in the schema.
fn graft_keyword(rng: &mut PropRng, v: &mut Value) {
    let keywords: &[(&str, fn(&mut PropRng) -> Value)] = &[
        ("minimum", |r| Value::Number(r.i64_in(-50, 50) as f64)),
        ("maximum", |r| Value::Number(r.i64_in(-50, 50) as f64)),
        ("minLength", |r| Value::Number(r.range(8) as f64)),
        ("maxLength", |r| Value::Number(r.range(8) as f64)),
        ("minItems", |r| Value::Number(r.range(5) as f64)),
        ("maxItems", |r| Value::Number(r.range(5) as f64)),
        ("pattern", |r| Value::String(if r.bool() { "^a+$".into() } else { "(".into() })),
        ("format", |r| Value::String(if r.bool() { "uuid".into() } else { "bogus".into() })),
        ("type", |r| {
            Value::String((*r.choose(&["string", "integer", "object", "bogus"])).into())
        }),
        ("required", |_| Value::Array(vec![Value::String("zzz".into())])),
        ("additionalProperties", |r| Value::Bool(r.bool())),
        ("items", |_| Value::Bool(false)),
    ];
    match v {
        Value::Object(o) => {
            // Either graft here or descend into a random entry.
            if o.is_empty() || rng.bool() {
                let (k, make) = *rng.choose(keywords);
                o.insert(k, make(rng));
            } else {
                let keys: Vec<String> = o.keys().cloned().collect();
                let k = rng.choose(&keys).clone();
                graft_keyword(rng, o.get_mut(&k).unwrap());
            }
        }
        Value::Array(items) if !items.is_empty() => {
            let i = rng.range(items.len());
            graft_keyword(rng, &mut items[i]);
        }
        _ => {}
    }
}
