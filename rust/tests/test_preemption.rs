//! Preemption-equivalence tier: KV eviction + recompute-on-resume is a
//! *scheduling* decision, never an output decision.
//!
//! The load-bearing properties, all on the deterministic reference
//! backend (no artifacts, runs everywhere):
//!   (a) any preempt/resume schedule yields token-identical output to an
//!       uninterrupted run — sampler, grammar, and stream state survive
//!       eviction because only KV residency is given up;
//!   (b) preempted pages are actually freed and re-allocatable;
//!   (c) mid-flight preemption composes with grammar fast-forward and
//!       speculative decoding without leaking pages;
//!   (d) a high-priority submit is never starved behind low-priority KV
//!       holders for more than one scheduler step.

use webllm::api::{ChatCompletionRequest, FinishReason, ResponseFormat};
use webllm::coordinator::{EngineConfig, EngineEvent, MLCEngine, RequestId};
use webllm::json::{parse, Value};
use webllm::testutil::ban_reference_eos as ban_eos;
use webllm::testutil::prop::Runner;

const MODEL: &str = "tiny-ref";
/// Divergent drafter (different depth/pool) so rejection paths run.
const DRAFT: &str = "tiny-ref-b";
/// Reference-model geometry (pinned by `models::reference` tests).
const PAGE: usize = 8;

fn engine() -> MLCEngine {
    MLCEngine::new(&EngineConfig::reference(&[MODEL])).expect("engine")
}

/// Greedy request over `'x' * k` (k + 4 prompt tokens, no merges).
fn xs_request(k: usize, max_tokens: usize) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new(MODEL).user("x".repeat(k));
    r.max_tokens = max_tokens;
    r.sampling.temperature = 0.0;
    ban_eos(&mut r);
    r
}

fn stat_i64(engine: &MLCEngine, key: &str) -> i64 {
    engine.stats_json().get(key).unwrap().as_i64().unwrap()
}

fn model_stat(engine: &MLCEngine, key: &str) -> i64 {
    engine
        .stats_json()
        .get("models")
        .and_then(|m| m.get(MODEL))
        .and_then(|m| m.get(key))
        .and_then(Value::as_i64)
        .unwrap()
}

/// Drive `engine` to completion, preempting `id` whenever `when` says so,
/// and return `id`'s response. Bounded so a scheduling bug fails loudly
/// instead of hanging the suite.
fn run_with_preemption(
    engine: &mut MLCEngine,
    id: RequestId,
    mut when: impl FnMut(usize) -> bool,
) -> webllm::api::ChatCompletionResponse {
    for step in 0..500 {
        if when(step) {
            engine.preempt(id);
        }
        engine.step().expect("step");
        for ev in engine.poll_events() {
            match ev {
                EngineEvent::Done(rid, resp) if rid == id => return resp,
                EngineEvent::Error(rid, e) if rid == id => panic!("request failed: {e}"),
                _ => {}
            }
        }
        if !engine.has_work() {
            break;
        }
    }
    panic!("request did not complete within 500 steps");
}

// -- (a) preemption equivalence ----------------------------------------------

#[test]
fn prop_any_preempt_schedule_is_output_invariant() {
    // Random prompt length (so preemptions land mid-prefill and
    // mid-decode), random seeded sampling, random preemption schedule:
    // the text must match the uninterrupted run bit for bit.
    Runner::new("preemption_equivalence", 6).run(|rng| {
        let k = rng.range(91); // prompt: k + 4 tokens
        let seed = rng.u64();
        let temperature = 0.2 + rng.f64() as f32;
        let mk = || {
            let mut r = ChatCompletionRequest::new(MODEL).user("x".repeat(k));
            r.max_tokens = 6;
            r.sampling.seed = Some(seed);
            r.sampling.temperature = temperature;
            ban_eos(&mut r);
            r
        };
        let baseline = engine().chat_completion(mk()).map_err(|e| e.to_string())?;

        // Preempt on roughly every third step, including step 0 (still
        // waiting: a no-op) and back-to-back evictions of a fresh resume.
        let schedule: Vec<bool> = (0..64).map(|_| rng.range(3) == 0).collect();
        let mut e = engine();
        let id = e.submit(mk()).map_err(|e| e.to_string())?;
        let resp = run_with_preemption(&mut e, id, |s| schedule.get(s).copied().unwrap_or(false));
        if resp.text() != baseline.text() {
            return Err(format!(
                "preempted run {:?} != baseline {:?} (prompt {k}, schedule {schedule:?})",
                resp.text(),
                baseline.text()
            ));
        }
        if resp.usage.completion_tokens != baseline.usage.completion_tokens {
            return Err("completion_tokens drifted under preemption".into());
        }
        Ok(())
    });
}

#[test]
fn preempt_every_step_still_terminates_identically() {
    // The adversarial schedule: evict the request before every single
    // scheduler step. Prefix-cached full pages bound the recompute, so
    // the run still makes monotonic progress and the output is unchanged.
    let baseline = engine().chat_completion(xs_request(60, 5)).unwrap();
    let mut e = engine();
    let id = e.submit(xs_request(60, 5)).unwrap();
    let resp = run_with_preemption(&mut e, id, |_| true);
    assert_eq!(resp.text(), baseline.text());
    assert_eq!(resp.usage.completion_tokens, 5);
    assert!(stat_i64(&e, "preemptions") > 0, "schedule never actually evicted");
}

// -- (b) pages are really freed ----------------------------------------------

#[test]
fn preempted_pages_are_freed_and_reallocatable() {
    let baseline = engine().chat_completion(xs_request(100, 12)).unwrap();

    let mut e = engine();
    let id = e.submit(xs_request(100, 12)).unwrap();
    // Prefill the 104-token prompt and decode a few tokens.
    for _ in 0..20 {
        e.step().unwrap();
        if model_stat(&e, "running") == 1 && stat_i64(&e, "decode_tokens") >= 3 {
            break;
        }
    }
    assert_eq!(model_stat(&e, "running"), 1, "sequence never reached decode");

    let before = model_stat(&e, "available_pages");
    assert!(e.preempt(id), "a running sequence holds pages");
    let after = model_stat(&e, "available_pages");
    // 104 prompt + decoded tokens span 14 pages; all of them must be
    // allocatable again (free or prefix-cached, both count).
    assert!(
        after >= before + 14,
        "eviction freed too little: {before} -> {after} available pages"
    );
    assert_eq!(model_stat(&e, "preempted"), 1);
    assert!(!e.preempt(id), "an evicted sequence holds no pages");
    assert!(!e.preempt(999_999), "unknown request holds no pages");

    // The freed pages are usable by someone else right now.
    let other = e.submit(xs_request(96, 2)).unwrap();
    e.run_to_completion().unwrap();
    let mut done = 0;
    for ev in e.poll_events() {
        if let EngineEvent::Done(rid, resp) = ev {
            done += 1;
            if rid == id {
                assert_eq!(resp.text(), baseline.text(), "resume changed the output");
                assert_eq!(resp.usage.completion_tokens, 12);
            } else {
                assert_eq!(rid, other);
            }
        }
    }
    assert_eq!(done, 2);
    assert_eq!(stat_i64(&e, "preemptions"), 1);
    // The evicted decode suffix sat on a partial page the prefix cache
    // can't keep, so the resume recomputed at least those positions.
    assert!(stat_i64(&e, "preempted_tokens_recomputed") >= 2);
}

// -- (c) composition with fast-forward + speculative decoding ----------------

#[test]
fn preemption_composes_with_grammar_fast_forward_and_speculation() {
    let spec_cfg = || {
        let mut cfg = EngineConfig::reference(&[MODEL]);
        cfg.draft_model = Some(DRAFT.to_string());
        cfg.enable_fast_forward = true;
        cfg
    };
    let schema = r#"{
        "type": "object",
        "properties": {"ok": {"type": "boolean"}, "n": {"type": "integer"}},
        "required": ["ok", "n"]
    }"#;
    let mk = || {
        let mut r = ChatCompletionRequest::new(MODEL).user("emit json");
        r.max_tokens = 100;
        r.sampling.temperature = 0.0;
        // '}' nudge closes the integer so greedy derivations finish early.
        r.sampling.logit_bias.insert(8 + b'}' as u32, 5.0);
        r.response_format = ResponseFormat::JsonSchema(parse(schema).unwrap());
        r
    };

    let baseline = MLCEngine::new(&spec_cfg()).unwrap().chat_completion(mk()).unwrap();
    assert!(parse(baseline.text()).is_ok(), "baseline must satisfy the schema");

    let mut e = MLCEngine::new(&spec_cfg()).unwrap();
    let idle_pages = model_stat(&e, "available_pages");
    let id = e.submit(mk()).unwrap();
    // Evict on every other step: mid-prefill first, then between
    // speculation rounds (draft KV mirror included).
    let resp = run_with_preemption(&mut e, id, |s| s % 2 == 0);
    assert_eq!(resp.text(), baseline.text(), "spec+grammar output changed");
    assert!(stat_i64(&e, "preemptions") > 0);

    // No garbage pages: with nothing in flight every page is allocatable
    // again, and a rerun on the same (warm) engine still agrees.
    assert!(!e.has_work());
    assert_eq!(model_stat(&e, "available_pages"), idle_pages, "pages leaked");
    let warm = e.chat_completion(mk()).unwrap();
    assert_eq!(warm.text(), baseline.text(), "preemption poisoned the prefix cache");
}

// -- (d) no priority inversion -----------------------------------------------

#[test]
fn high_priority_submit_preempts_within_one_step() {
    let mut e = engine();
    // Fill the pool: 4 greedy requests of 14 pages each (56 of the 63
    // usable pages), decoding long enough to still be live below.
    let mut low_ids = Vec::new();
    for _ in 0..4 {
        low_ids.push(e.submit(xs_request(100, 16)).unwrap());
    }
    for _ in 0..200 {
        e.step().unwrap();
        if model_stat(&e, "running") == 4 {
            break;
        }
    }
    assert_eq!(model_stat(&e, "running"), 4, "pool never filled");

    // 14 needed > 7 available: admission must evict a low-priority
    // victim rather than queue behind it.
    let high = e.submit(xs_request(100, 4).with_priority(5)).unwrap();
    e.step().unwrap();
    let stats = e.stats_json();
    let m = stats.get("models").unwrap().get(MODEL).unwrap();
    assert!(
        m.get("queued_by_priority").unwrap().get("5").is_none(),
        "high-priority request still queued after one step: {}",
        webllm::json::to_string(m)
    );
    assert_eq!(m.get("preempted").unwrap().as_i64(), Some(1));
    assert_eq!(stat_i64(&e, "preemptions"), 1);

    // Everyone still completes, and the evicted victim's output is the
    // same as an unpreempted solo run (scheduler-triggered eviction goes
    // through exactly the machinery properties (a)-(b) pinned).
    let victim_baseline = engine().chat_completion(xs_request(100, 16)).unwrap();
    e.run_to_completion().unwrap();
    let mut done = 0;
    let mut saw_high = false;
    for ev in e.poll_events() {
        if let EngineEvent::Done(rid, resp) = ev {
            done += 1;
            assert_eq!(resp.choices[0].finish_reason, FinishReason::Length);
            if rid == high {
                saw_high = true;
                assert_eq!(resp.usage.completion_tokens, 4);
            } else {
                assert!(low_ids.contains(&rid));
                assert_eq!(resp.text(), victim_baseline.text());
            }
        }
    }
    assert_eq!(done, 5);
    assert!(saw_high);
}

#[test]
fn prefill_chunks_go_to_the_highest_priority_class() {
    // Two long prompts admitted together; the high-priority one owns
    // every chunk until it finishes, so it reaches its first token
    // first even though it arrived second.
    let mut e = engine();
    let lo = e.submit(xs_request(90, 30)).unwrap();
    let hi = e.submit(xs_request(91, 2).with_priority(3)).unwrap();
    let mut first_done = None;
    for _ in 0..200 {
        e.step().unwrap();
        for ev in e.poll_events() {
            if let EngineEvent::Done(rid, _) = ev {
                first_done.get_or_insert(rid);
            }
        }
        if !e.has_work() {
            break;
        }
    }
    assert_eq!(first_done, Some(hi), "high priority must finish first");
    let _ = lo;
}

// -- back-pressure ------------------------------------------------------------

#[test]
fn submit_rejects_with_queue_full_at_the_waiting_cap() {
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.max_waiting_requests = 1;
    let mut e = MLCEngine::new(&cfg).unwrap();
    e.submit(xs_request(4, 2)).unwrap();
    let err = e.submit(xs_request(5, 2)).unwrap_err();
    assert_eq!(err.status, 429);
    assert_eq!(err.kind, "queue_full");
    assert!(err.message.contains("retry"), "{}", err.message);
    // Draining the queue reopens admission.
    e.run_to_completion().unwrap();
    e.submit(xs_request(5, 2)).unwrap();
    e.run_to_completion().unwrap();
    assert_eq!(
        e.poll_events()
            .iter()
            .filter(|ev| matches!(ev, EngineEvent::Done(..)))
            .count(),
        2
    );
}

// -- stats surface ------------------------------------------------------------

#[test]
fn queue_depth_stats_group_by_priority_class() {
    let mut e = engine();
    for p in [0, 0, 2, -1] {
        e.submit(xs_request(6, 1).with_priority(p)).unwrap();
    }
    let stats = e.stats_json();
    let q = stats
        .get("models")
        .unwrap()
        .get(MODEL)
        .unwrap()
        .get("queued_by_priority")
        .unwrap();
    assert_eq!(q.get("0").unwrap().as_i64(), Some(2));
    assert_eq!(q.get("2").unwrap().as_i64(), Some(1));
    assert_eq!(q.get("-1").unwrap().as_i64(), Some(1));
    e.run_to_completion().unwrap();
    assert_eq!(stat_i64(&e, "preemptions"), 0, "{} tokens fit without eviction", PAGE);
}
