//! Multi-token emission: grammar fast-forward + draft-model speculative
//! decoding, end to end on the deterministic reference backend.
//!
//! The load-bearing property throughout: everything here is an
//! *optimization of the schedule*, never of the output. A speculative
//! engine (with or without fast-forward) must produce token-for-token
//! the text a plain one-token-per-step engine produces, because every
//! emitted token is chosen by the request's own sampler from logits the
//! target model computed. These tests pin that equivalence, the stats
//! accounting, and the KV-rollback hygiene around aborts.

use webllm::api::{ChatCompletionRequest, FinishReason, ResponseFormat};
use webllm::coordinator::{EngineConfig, MLCEngine};
use webllm::json::parse;
use webllm::testutil::ban_reference_eos as ban_eos;
use webllm::testutil::prop::Runner;

const MODEL: &str = "tiny-ref";
/// Same architecture as the target: proposals nearly always accepted.
const SELF_DRAFT: &str = "tiny-ref";
/// Different depth/pool: a genuinely divergent drafter, so rejection and
/// KV rollback paths actually run.
const OTHER_DRAFT: &str = "tiny-ref-b";

/// One-token-per-step baseline: no draft, no fast-forward.
fn baseline_engine() -> MLCEngine {
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.enable_fast_forward = false;
    MLCEngine::new(&cfg).expect("baseline engine")
}

/// Speculative engine: `draft` proposes, fast-forward per `ff`.
fn spec_engine(draft: &str, ff: bool) -> MLCEngine {
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.draft_model = Some(draft.to_string());
    cfg.enable_fast_forward = ff;
    MLCEngine::new(&cfg).expect("spec engine")
}

fn greedy(prompt: &str, max_tokens: usize) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new(MODEL).user(prompt);
    r.max_tokens = max_tokens;
    r.sampling.temperature = 0.0;
    r
}

/// Byte-token id in the reference tokenizer (byte_offset 8).
const fn byte_tok(b: u8) -> u32 {
    8 + b as u32
}

/// The ok/n JSON-schema request used across the structured tests: the
/// '}' nudge closes the integer after a few digits so greedy derivations
/// finish well inside max_tokens.
fn schema_request(prompt: &str) -> ChatCompletionRequest {
    let schema = r#"{
        "type": "object",
        "properties": {"ok": {"type": "boolean"}, "n": {"type": "integer"}},
        "required": ["ok", "n"]
    }"#;
    let mut req = greedy(prompt, 100);
    req.sampling.logit_bias.insert(byte_tok(b'}'), 5.0);
    req.response_format = ResponseFormat::JsonSchema(parse(schema).unwrap());
    req
}

// -- output equivalence -----------------------------------------------------

#[test]
fn prop_spec_greedy_grammar_matches_plain_baseline() {
    // Greedy + grammar + fast-forward + speculation (both drafters) must
    // reproduce the plain engine's output exactly: greedy draws no RNG,
    // so even skipped single-candidate states can't shift the stream.
    let prompts = ["emit json", "structured output", "fill the schema", "data"];
    let grammars: &[fn(&str) -> ChatCompletionRequest] = &[
        |p| schema_request(p),
        |p| {
            let mut r = greedy(p, 16);
            r.response_format = ResponseFormat::Grammar(r#"root ::= "yes" | "no""#.into());
            r
        },
        |p| {
            let mut r = greedy(p, 32);
            r.response_format =
                ResponseFormat::Grammar(r#"root ::= "status: " ("ok" | "fail") "!""#.into());
            r
        },
    ];
    Runner::new("spec_greedy_grammar_parity", 6).run(|rng| {
        let prompt = *rng.choose(&prompts);
        let mk = *rng.choose(grammars);
        let draft = if rng.bool() { SELF_DRAFT } else { OTHER_DRAFT };
        let want = baseline_engine().chat_completion(mk(prompt)).map_err(|e| e.to_string())?;
        let mut spec = spec_engine(draft, true);
        let got = spec.chat_completion(mk(prompt)).map_err(|e| e.to_string())?;
        if want.text() != got.text() {
            return Err(format!(
                "draft {draft} prompt {prompt:?}: {:?} != baseline {:?}",
                got.text(),
                want.text()
            ));
        }
        if want.usage.completion_tokens != got.usage.completion_tokens {
            return Err(format!(
                "token counts diverged: {} != {}",
                got.usage.completion_tokens, want.usage.completion_tokens
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_spec_sampled_no_grammar_matches_plain_baseline() {
    // At temperature > 0 the equivalence still holds without a grammar:
    // each emitted token consumes exactly one sampler draw over logits
    // identical to plain decode's, whether it came from a verify row or
    // a plain step. (Fast-forward is a no-op without a grammar.)
    let prompts = ["alpha", "speculative stream", "hello world", "determinism"];
    Runner::new("spec_sampled_parity", 6).run(|rng| {
        let seed = rng.u64();
        let prompt = *rng.choose(&prompts);
        let temperature = 0.2 + rng.f64() as f32;
        let draft = if rng.bool() { SELF_DRAFT } else { OTHER_DRAFT };
        let mk = || {
            let mut r = ChatCompletionRequest::new(MODEL).user(prompt);
            r.max_tokens = 10;
            r.sampling.seed = Some(seed);
            r.sampling.temperature = temperature;
            r
        };
        let want = baseline_engine().chat_completion(mk()).map_err(|e| e.to_string())?;
        let got = spec_engine(draft, true).chat_completion(mk()).map_err(|e| e.to_string())?;
        if want.text() != got.text() {
            return Err(format!(
                "seed {seed} temp {temperature} draft {draft}: {:?} != baseline {:?}",
                got.text(),
                want.text()
            ));
        }
        Ok(())
    });
}

// -- fast-forward -----------------------------------------------------------

#[test]
fn fast_forward_emits_forced_runs_without_model_calls() {
    // A 40-byte literal after one free choice: every post-choice state
    // forces a single token, so fast-forward must emit nearly the whole
    // derivation from the cached forced runs, in a handful of steps.
    let literal = "abcdefghijklmnopqrstuvwxyz0123456789!?.,";
    let grammar = format!("root ::= (\"L\" | \"R\") \"{literal}\"");
    let mk = || {
        let mut r = greedy("pick a side", 60);
        r.response_format = ResponseFormat::Grammar(grammar.clone());
        r
    };

    let mut ff = MLCEngine::new(&EngineConfig::reference(&[MODEL])).unwrap();
    let resp = ff.chat_completion(mk()).unwrap();
    assert_eq!(resp.choices[0].finish_reason, FinishReason::Stop);
    assert!(resp.text().ends_with(literal), "{:?}", resp.text());

    let stats = ff.stats_json();
    let spec = stats.get("speculative").unwrap();
    let ff_tokens = spec.get("ff_tokens").unwrap().as_i64().unwrap();
    assert!(ff_tokens >= literal.len() as i64, "forced run not fast-forwarded: {ff_tokens}");
    // The literal's tokens never hit the model: far fewer decode-path
    // samples than completion tokens.
    let decode_tokens = stats.get("decode_tokens").unwrap().as_i64().unwrap();
    assert!(
        (decode_tokens as usize) < resp.usage.completion_tokens,
        "decode_tokens {decode_tokens} >= completion {}",
        resp.usage.completion_tokens
    );

    // And the output is exactly what the one-token-per-step engine says.
    let want = baseline_engine().chat_completion(mk()).unwrap();
    assert_eq!(resp.text(), want.text());
    assert_eq!(resp.usage.completion_tokens, want.usage.completion_tokens);
}

// -- stats accounting -------------------------------------------------------

#[test]
fn self_draft_accepts_nearly_everything() {
    // Drafting with the target's own architecture and seed: proposals
    // are the target's own argmax, so acceptance is near-total (only a
    // Length cutoff mid-round leaves scored-but-unreached proposals).
    let mut engine = spec_engine(SELF_DRAFT, true);
    let mut req = greedy("steady stream of tokens", 24);
    ban_eos(&mut req);
    engine.chat_completion(req).unwrap();

    let stats = engine.stats_json();
    let spec = stats.get("speculative").unwrap();
    let steps = spec.get("spec_steps").unwrap().as_i64().unwrap();
    let proposed = spec.get("draft_proposed").unwrap().as_i64().unwrap();
    let accepted = spec.get("draft_accepted").unwrap().as_i64().unwrap();
    let rate = spec.get("draft_accept_rate").unwrap().as_f64().unwrap();
    assert!(steps > 0, "no speculative steps ran");
    assert!(proposed >= steps, "each spec step proposes at least one token");
    assert!(accepted > 0);
    assert!(rate > 0.7, "self-draft accept rate {rate} unexpectedly low");
    // Multi-token emission actually happened: more tokens than target
    // model calls (decode steps), the whole point of speculation.
    let decode_tokens = stats.get("decode_tokens").unwrap().as_i64().unwrap();
    let decode_steps = stats.get("decode_steps").unwrap().as_i64().unwrap();
    assert!(
        decode_tokens > decode_steps,
        "no step emitted more than one token ({decode_tokens} tokens / {decode_steps} steps)"
    );
}

#[test]
fn spec_and_ff_compose_on_constrained_json() {
    // The composed path: forced spans fast-forward, free spans go
    // through grammar-constrained speculation — both counters move, and
    // the output still matches the plain baseline.
    let mut engine = spec_engine(OTHER_DRAFT, true);
    let resp = engine.chat_completion(schema_request("emit json")).unwrap();
    let v = parse(resp.text()).unwrap_or_else(|e| panic!("not JSON: {e}: {}", resp.text()));
    assert!(v.get("ok").is_some() && v.get("n").is_some(), "{}", resp.text());

    let stats = engine.stats_json();
    let spec = stats.get("speculative").unwrap();
    assert!(spec.get("ff_tokens").unwrap().as_i64().unwrap() > 0, "schema has forced spans");
    assert!(spec.get("spec_steps").unwrap().as_i64().unwrap() > 0, "free spans speculate");

    let want = baseline_engine().chat_completion(schema_request("emit json")).unwrap();
    assert_eq!(resp.text(), want.text());
}

// -- abort / KV hygiene -----------------------------------------------------

#[test]
fn abort_mid_spec_leaves_no_reusable_garbage() {
    // Abort a speculative, grammar-constrained request mid-run — right
    // when the target KV may hold rejected draft tokens past the
    // `written` watermark — then rerun the identical request on the same
    // engine. If freeing the aborted sequence had registered any
    // partially-garbage page in the prefix cache, the rerun would reuse
    // it and diverge from a fresh baseline; instead both must agree.
    let mk = || {
        let mut r = greedy("long structured run", 40);
        r.response_format =
            ResponseFormat::Grammar(format!("root ::= (\"L\" | \"R\") \"{}\"", "a".repeat(60)));
        r
    };
    let mut engine = spec_engine(OTHER_DRAFT, false);
    let id = engine.submit(mk()).unwrap();
    for _ in 0..3 {
        engine.step().unwrap();
    }
    engine.abort(id);
    engine.run_to_completion().unwrap();
    let mut aborted = None;
    for ev in engine.poll_events() {
        if let webllm::coordinator::EngineEvent::Done(rid, resp) = ev {
            if rid == id {
                aborted = Some(resp);
            }
        }
    }
    let aborted = aborted.expect("aborted request resolves with a response");
    assert_eq!(aborted.choices[0].finish_reason, FinishReason::Abort);

    // Rerun on the same engine (prefix cache warm from the abort) and on
    // a fresh baseline: byte-identical completions.
    let rerun = engine.chat_completion(mk()).unwrap();
    let mut fresh = baseline_engine();
    let want = fresh.chat_completion(mk()).unwrap();
    assert_eq!(rerun.text(), want.text(), "aborted KV leaked into a reused page");
    assert_eq!(rerun.usage.completion_tokens, want.usage.completion_tokens);

    // All pages returned: the engine can still admit and serve requests
    // back to back (nothing leaked to the draft mirror either).
    let again = engine.chat_completion(mk()).unwrap();
    assert_eq!(again.text(), want.text());
}
