//! Reference-backend contract tests: the shared backend-conformance
//! suite (`testutil::backend_contract`, the same checks `test_runtime.rs`
//! runs against compiled XLA artifacts) executed unconditionally with
//! **exact equality**, plus the reference backend's stricter guarantees —
//! hard errors on unwritten-KV reads, all-zero padding rows, seed/model
//! identity — that the shared contract deliberately leaves unspecified.

use webllm::models::reference_model_config;
use webllm::runtime::{ModelBackend, ReferenceBackend};
use webllm::testutil::backend_contract::{padded, BackendConformance};

fn backend() -> Box<dyn ModelBackend> {
    Box::new(ReferenceBackend::new(
        reference_model_config("tiny-ref").unwrap(),
        7,
        Some(2),
        None,
    ))
}

fn conformance() -> BackendConformance {
    BackendConformance::new(backend) // tol 0.0: exact equality
}

// -- shared conformance suite (exact) ---------------------------------------

#[test]
fn conformance_reports_compiled_shapes() {
    conformance().reports_compiled_shapes();
    // Reference-registry specifics on top of the generic check.
    let rt = backend();
    assert_eq!(rt.compiled_chunks(), vec![16, 32, 64]);
    assert_eq!(rt.compiled_batches(), vec![1, 2, 4, 8]);
    assert_eq!(rt.config().name, "tiny-ref");
}

#[test]
fn conformance_shape_errors_are_reported() {
    conformance().shape_errors_are_reported();
    // Stricter-than-contract reference checks.
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    // page id out of pool
    let mut bad = vec![0i32; mp];
    bad[0] = 10_000;
    assert!(rt.prefill(&[0; 16], 4, &bad).is_err());
    // position not seq_len-1
    assert!(rt.decode(&[0; 1], &[5], &[3], &vec![0; mp]).is_err());
}

#[test]
fn conformance_kv_cache_chains_across_steps() {
    conformance().kv_cache_chains_across_steps();
}

#[test]
fn conformance_reset_cache_restores_initial_state() {
    conformance().reset_cache_restores_initial_state();
}

#[test]
fn conformance_batch_menu_is_transparent() {
    conformance().batch_menu_is_transparent();
}

#[test]
fn conformance_logits_address_page_contents_not_page_ids() {
    conformance().logits_address_page_contents_not_page_ids();
}

#[test]
fn conformance_chunked_prefill_matches_whole_prompt() {
    conformance().chunked_prefill_matches_whole_prompt();
}

#[test]
fn conformance_chunked_prefill_reads_resident_prefix_pages() {
    conformance().chunked_prefill_reads_resident_prefix_pages();
}

#[test]
fn conformance_recompute_after_reset_matches_uninterrupted_chain() {
    conformance().recompute_after_reset_matches_uninterrupted_chain();
}

// -- reference-specific strictness ------------------------------------------

#[test]
fn padding_rows_are_all_zero() {
    // The shared contract only pins live rows; the reference backend
    // additionally zeroes padding rows so leakage is detectable.
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap();
    let mut bt2 = vec![0i32; 2 * mp];
    bt2[..mp].copy_from_slice(&bt);
    let out = rt.decode(&[9, 0], &[2, 0], &[3, 0], &bt2).unwrap();
    let v = rt.config().vocab_size;
    assert!(out.logits[v..].iter().all(|&x| x == 0.0), "padding row leaked");
}

#[test]
fn reading_unwritten_kv_is_an_error() {
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 3;
    // Decode claims a 4-token prefix that was never prefilled.
    let err = rt.decode(&[9], &[3], &[4], &bt).unwrap_err();
    assert!(err.to_string().contains("read before any write"), "{err}");
}

#[test]
fn chunk_over_unwritten_prefix_is_an_error() {
    // A positioned chunk claiming residency below start_pos that nothing
    // ever wrote: the exact failure a bogus prefix skip would cause.
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    bt[1] = 2;
    let err = rt.prefill_chunk(&padded(&[9, 9], 16), 6, 2, &bt).unwrap_err();
    assert!(err.to_string().contains("read before any write"), "{err}");
}

#[test]
fn shared_prefix_pages_are_readable_by_both_sequences() {
    // Prefix-cache shape: sequence B's table points at A's first page
    // (same first 8 tokens), then diverges. Both must decode fine, and
    // B's logits must reflect its own full prefix.
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let first_page: Vec<i32> = (100..108).collect();

    let mut ids_a = first_page.clone();
    ids_a.extend_from_slice(&[1, 2]);
    let mut bt_a = vec![0i32; mp];
    bt_a[0] = 1;
    bt_a[1] = 2;
    rt.prefill(&padded(&ids_a, 16), 10, &bt_a).unwrap();

    // B shares page 1 (identical first 8 tokens), diverges in page 3.
    let mut ids_b = first_page.clone();
    ids_b.extend_from_slice(&[3, 4]);
    let mut bt_b = vec![0i32; mp];
    bt_b[0] = 1;
    bt_b[1] = 3;
    let b = rt.prefill(&padded(&ids_b, 16), 10, &bt_b).unwrap();

    // An unshared replay of B's exact prefix agrees bit-for-bit.
    let mut rt2 = backend();
    let mut bt_c = vec![0i32; mp];
    bt_c[0] = 7;
    bt_c[1] = 8;
    let c = rt2.prefill(&padded(&ids_b, 16), 10, &bt_c).unwrap();
    assert_eq!(b.logits, c.logits, "shared-page prefix must be transparent");
}

#[test]
fn dispatches_and_exec_time_reported() {
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    let out = rt.prefill(&padded(&[3], 16), 1, &bt).unwrap();
    // 2 layers x 11 + 3 (same estimate as the XLA runtime).
    assert_eq!(out.dispatches, 25);
    assert!(out.exec_seconds >= 0.0);
}

#[test]
fn seed_and_model_identity_change_logits() {
    let cfg = reference_model_config("tiny-ref").unwrap();
    let mp = cfg.max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    let ids = padded(&[50, 51], 16);

    let mut s7 = ReferenceBackend::new(cfg.clone(), 7, None, None);
    let mut s8 = ReferenceBackend::new(cfg.clone(), 8, None, None);
    let a = s7.prefill(&ids, 2, &bt).unwrap();
    let b = s8.prefill(&ids, 2, &bt).unwrap();
    assert_ne!(a.logits, b.logits, "engine seed must matter");

    let mut other =
        ReferenceBackend::new(reference_model_config("tiny-ref-b").unwrap(), 7, None, None);
    let c = other.prefill(&ids, 2, &bt).unwrap();
    assert_ne!(a.logits, c.logits, "model identity must matter");
}
