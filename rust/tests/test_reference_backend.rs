//! Reference-backend contract tests: the same runtime-layer checks
//! `test_runtime.rs` runs against compiled XLA artifacts, executed
//! unconditionally against `ReferenceBackend` through the `ModelBackend`
//! trait object — shape validation, KV chaining, batch transparency,
//! page-content addressing, and reset semantics.

use webllm::models::reference_model_config;
use webllm::runtime::{ModelBackend, ReferenceBackend};

fn backend() -> Box<dyn ModelBackend> {
    Box::new(ReferenceBackend::new(
        reference_model_config("tiny-ref").unwrap(),
        7,
        Some(2),
        None,
    ))
}

fn padded(ids: &[i32], chunk: usize) -> Vec<i32> {
    let mut v = vec![0i32; chunk];
    v[..ids.len()].copy_from_slice(ids);
    v
}

#[test]
fn reports_compiled_shapes() {
    let rt = backend();
    assert_eq!(rt.compiled_chunks(), vec![16, 32, 64]);
    assert_eq!(rt.compiled_batches(), vec![1, 2, 4, 8]);
    assert!(rt.load_seconds() >= 0.0);
    assert!(rt.weight_bytes() > 0);
    assert_eq!(rt.config().name, "tiny-ref");
}

#[test]
fn shape_errors_are_reported() {
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    // wrong chunk
    assert!(rt.prefill(&[0; 24], 4, &vec![0; mp]).is_err());
    // wrong block table length
    assert!(rt.prefill(&[0; 16], 4, &[0; 3]).is_err());
    // zero seq_len
    assert!(rt.prefill(&[0; 16], 0, &vec![0; mp]).is_err());
    // seq_len beyond chunk
    assert!(rt.prefill(&[0; 16], 17, &vec![0; mp]).is_err());
    // page id out of pool
    let mut bad = vec![0i32; mp];
    bad[0] = 10_000;
    assert!(rt.prefill(&[0; 16], 4, &bad).is_err());
    // wrong batch
    assert!(rt.decode(&[0; 3], &[0; 3], &[0; 3], &vec![0; 3 * mp]).is_err());
    // inconsistent lengths
    assert!(rt.decode(&[0; 1], &[0; 2], &[0; 1], &vec![0; mp]).is_err());
    // position not seq_len-1
    assert!(rt.decode(&[0; 1], &[5], &[3], &vec![0; mp]).is_err());
}

#[test]
fn prefill_then_decode_logits_change_with_context() {
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    bt[1] = 2;

    let out = rt.prefill(&padded(&[10, 11, 12, 13], 16), 4, &bt).unwrap();
    assert_eq!(out.logits.len(), rt.config().vocab_size);

    // Decode the same next token twice at successive positions: context
    // grew, so logits must differ (cache actually chained).
    let one = rt.decode(&[42], &[4], &[5], &bt).unwrap();
    let two = rt.decode(&[42], &[5], &[6], &bt).unwrap();
    let d: f32 = one
        .logits
        .iter()
        .zip(&two.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(d > 1e-6, "cache state did not affect logits");
}

#[test]
fn reset_cache_restores_initial_state() {
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;

    let ids = padded(&[7, 8, 9], 16);
    let a = rt.prefill(&ids, 3, &bt).unwrap();
    // pollute cache, then reset, then repeat: identical logits expected
    rt.decode(&[1], &[3], &[4], &bt).unwrap();
    rt.reset_cache().unwrap();
    let b = rt.prefill(&ids, 3, &bt).unwrap();
    assert_eq!(a.logits, b.logits);
}

#[test]
fn batch_sizes_agree_on_shared_sequence() {
    // The same single sequence decoded through the b=1 and b=2 menus
    // (padding the second slot) must produce identical logits — the
    // static-shape menu must be semantically transparent.
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;

    let ids = padded(&[5, 6], 16);
    rt.prefill(&ids, 2, &bt).unwrap();
    let one = rt.decode(&[9], &[2], &[3], &bt).unwrap();

    // Fresh backend to replay with b=2 (cache state must match).
    let mut rt2 = backend();
    rt2.prefill(&ids, 2, &bt).unwrap();
    let mut bt2 = vec![0i32; 2 * mp];
    bt2[..mp].copy_from_slice(&bt);
    let two = rt2.decode(&[9, 0], &[2, 0], &[3, 0], &bt2).unwrap();

    let v = rt.config().vocab_size;
    assert_eq!(one.logits[..v], two.logits[..v], "b=1 vs b=2 logits diverge");
    // Padding row contributed nothing.
    assert!(two.logits[v..].iter().all(|&x| x == 0.0));
}

#[test]
fn logits_address_page_contents_not_page_ids() {
    // Two sequences with identical token prefixes but different page
    // assignments must see identical logits: the KV contract is
    // content-addressed through the block table.
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let ids = padded(&[21, 22, 23, 24, 25, 26, 27, 28, 29], 16);

    let mut bt_a = vec![0i32; mp];
    bt_a[0] = 1;
    bt_a[1] = 2;
    let a = rt.prefill(&ids, 9, &bt_a).unwrap();

    let mut bt_b = vec![0i32; mp];
    bt_b[0] = 5;
    bt_b[1] = 6;
    let b = rt.prefill(&ids, 9, &bt_b).unwrap();
    assert_eq!(a.logits, b.logits, "page ids leaked into the logits");
}

#[test]
fn shared_prefix_pages_are_readable_by_both_sequences() {
    // Prefix-cache shape: sequence B's table points at A's first page
    // (same first 8 tokens), then diverges. Both must decode fine, and
    // B's logits must reflect its own full prefix.
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let first_page: Vec<i32> = (100..108).collect();

    let mut ids_a = first_page.clone();
    ids_a.extend_from_slice(&[1, 2]);
    let mut bt_a = vec![0i32; mp];
    bt_a[0] = 1;
    bt_a[1] = 2;
    rt.prefill(&padded(&ids_a, 16), 10, &bt_a).unwrap();

    // B shares page 1 (identical first 8 tokens), diverges in page 3.
    let mut ids_b = first_page.clone();
    ids_b.extend_from_slice(&[3, 4]);
    let mut bt_b = vec![0i32; mp];
    bt_b[0] = 1;
    bt_b[1] = 3;
    let b = rt.prefill(&padded(&ids_b, 16), 10, &bt_b).unwrap();

    // An unshared replay of B's exact prefix agrees bit-for-bit.
    let mut rt2 = backend();
    let mut bt_c = vec![0i32; mp];
    bt_c[0] = 7;
    bt_c[1] = 8;
    let c = rt2.prefill(&padded(&ids_b, 16), 10, &bt_c).unwrap();
    assert_eq!(b.logits, c.logits, "shared-page prefix must be transparent");
}

#[test]
fn dispatches_and_exec_time_reported() {
    let mut rt = backend();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    let out = rt.prefill(&padded(&[3], 16), 1, &bt).unwrap();
    // 2 layers x 11 + 3 (same estimate as the XLA runtime).
    assert_eq!(out.dispatches, 25);
    assert!(out.exec_seconds >= 0.0);
}

#[test]
fn seed_and_model_identity_change_logits() {
    let cfg = reference_model_config("tiny-ref").unwrap();
    let mp = cfg.max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    let ids = padded(&[50, 51], 16);

    let mut s7 = ReferenceBackend::new(cfg.clone(), 7, None, None);
    let mut s8 = ReferenceBackend::new(cfg.clone(), 8, None, None);
    let a = s7.prefill(&ids, 2, &bt).unwrap();
    let b = s8.prefill(&ids, 2, &bt).unwrap();
    assert_ne!(a.logits, b.logits, "engine seed must matter");

    let mut other =
        ReferenceBackend::new(reference_model_config("tiny-ref-b").unwrap(), 7, None, None);
    let c = other.prefill(&ids, 2, &bt).unwrap();
    assert_ne!(a.logits, c.logits, "model identity must matter");
}
