//! Runtime-layer integration for the **XLA backend**: artifact loading,
//! shape validation, cache chaining, and numeric agreement between
//! compiled batch sizes.
//!
//! These tests need compiled artifacts (`make artifacts`) and log a
//! `SKIP:` marker when they are absent — CI greps the *reference*
//! suites' output to ensure no reference test ever prints one. The same
//! contract is exercised artifact-free in `test_reference_backend.rs`.

use webllm::models::Manifest;
use webllm::runtime::{thread_client, ModelRuntime};

fn manifest() -> Option<Manifest> {
    let dir = webllm::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "SKIP: XLA artifacts not found in {} (run `make artifacts`); \
             skipping XLA-specific runtime test",
            dir.display()
        );
        return None;
    }
    Some(Manifest::load(&dir).unwrap())
}

#[test]
fn load_reports_compiled_shapes() {
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let rt = ModelRuntime::load(&client, &m, "tiny-2m", None).unwrap();
    assert_eq!(rt.compiled_chunks(), vec![16, 32, 64, 128]);
    assert_eq!(rt.compiled_batches(), vec![1, 2, 4]);
    assert!(rt.load_seconds > 0.0);
}

#[test]
fn load_subset_restricts_compilation() {
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let rt =
        ModelRuntime::load_subset(&client, &m, "tiny-2m", None, Some(&[16]), Some(&[1])).unwrap();
    assert_eq!(rt.compiled_chunks(), vec![16]);
    assert_eq!(rt.compiled_batches(), vec![1]);
}

#[test]
fn shape_errors_are_reported() {
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let mut rt = ModelRuntime::load_subset(&client, &m, "tiny-2m", None, Some(&[16]), Some(&[1]))
        .unwrap();
    let mp = rt.config().max_pages_per_seq();
    // wrong chunk
    assert!(rt.prefill(&[0; 24], 4, &vec![0; mp]).is_err());
    // wrong block table length
    assert!(rt.prefill(&[0; 16], 4, &[0; 3]).is_err());
    // zero seq_len
    assert!(rt.prefill(&[0; 16], 0, &vec![0; mp]).is_err());
    // wrong batch
    assert!(rt.decode(&[0; 3], &[0; 3], &[0; 3], &vec![0; 3 * mp]).is_err());
    // inconsistent lengths
    assert!(rt.decode(&[0; 1], &[0; 2], &[0; 1], &vec![0; mp]).is_err());
}

#[test]
fn prefill_then_decode_logits_change_with_context() {
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let mut rt = ModelRuntime::load(&client, &m, "tiny-2m", None).unwrap();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    bt[1] = 2;

    let mut ids = vec![0i32; 16];
    ids[..4].copy_from_slice(&[10, 11, 12, 13]);
    let out = rt.prefill(&ids, 4, &bt).unwrap();
    assert_eq!(out.logits.len(), rt.config().vocab_size);

    // Decode the same next token twice at successive positions: context
    // grew, so logits must differ (cache actually chained).
    let one = rt.decode(&[42], &[4], &[5], &bt).unwrap();
    let two = rt.decode(&[42], &[5], &[6], &bt).unwrap();
    let d: f32 = one
        .logits
        .iter()
        .zip(&two.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(d > 1e-6, "cache state did not affect logits");
}

#[test]
fn reset_cache_restores_initial_state() {
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let mut rt = ModelRuntime::load_subset(&client, &m, "tiny-2m", None, Some(&[16]), Some(&[1]))
        .unwrap();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;

    let mut ids = vec![0i32; 16];
    ids[..3].copy_from_slice(&[7, 8, 9]);
    let a = rt.prefill(&ids, 3, &bt).unwrap();
    // pollute cache, then reset, then repeat: identical logits expected
    rt.decode(&[1], &[3], &[4], &bt).unwrap();
    rt.reset_cache().unwrap();
    let b = rt.prefill(&ids, 3, &bt).unwrap();
    assert_eq!(a.logits, b.logits);
}

#[test]
fn batch_sizes_agree_on_shared_sequence() {
    // The same single sequence decoded through the b=1 and b=2 executables
    // (padding the second slot) must produce identical logits — the
    // static-shape menu must be semantically transparent.
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let mut rt = ModelRuntime::load(&client, &m, "tiny-2m", None).unwrap();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;

    let mut ids = vec![0i32; 16];
    ids[..2].copy_from_slice(&[5, 6]);
    rt.prefill(&ids, 2, &bt).unwrap();

    let one = rt.decode(&[9], &[2], &[3], &bt).unwrap();

    // Fresh runtime to replay with b=2 (cache state must match).
    let mut rt2 = ModelRuntime::load(&client, &m, "tiny-2m", None).unwrap();
    rt2.prefill(&ids, 2, &bt).unwrap();
    let mut bt2 = vec![0i32; 2 * mp];
    bt2[..mp].copy_from_slice(&bt);
    let two = rt2.decode(&[9, 0], &[2, 0], &[3, 0], &bt2).unwrap();

    let v = rt.config().vocab_size;
    let max_diff: f32 = one.logits[..v]
        .iter()
        .zip(&two.logits[..v])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(max_diff < 1e-4, "b=1 vs b=2 logits diverge: {max_diff}");
}

#[test]
fn dispatches_and_exec_time_reported() {
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let mut rt = ModelRuntime::load_subset(&client, &m, "tiny-2m", None, Some(&[16]), Some(&[1]))
        .unwrap();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    let mut ids = vec![0i32; 16];
    ids[0] = 3;
    let out = rt.prefill(&ids, 1, &bt).unwrap();
    // 2 layers x 11 + 3
    assert_eq!(out.dispatches, 25);
    assert!(out.exec_seconds > 0.0);
}
