//! Runtime-layer integration for the **XLA backend**: artifact loading,
//! plus the shared backend-conformance suite
//! (`testutil::backend_contract`) run with a small float tolerance —
//! the same checks `test_reference_backend.rs` runs exactly.
//!
//! These tests need compiled artifacts (`make artifacts`) and log a
//! `SKIP:` marker when they are absent — CI greps the *reference*
//! suites' output to ensure no reference test ever prints one.

use webllm::models::Manifest;
use webllm::runtime::{thread_client, ModelRuntime};
use webllm::testutil::backend_contract::BackendConformance;

fn manifest() -> Option<Manifest> {
    let dir = webllm::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "SKIP: XLA artifacts not found in {} (run `make artifacts`); \
             skipping XLA-specific runtime test",
            dir.display()
        );
        return None;
    }
    Some(Manifest::load(&dir).unwrap())
}

/// Kernel reassociation across compiled shapes: logits that the contract
/// calls "equal" may differ by float noise on the XLA path.
const XLA_TOL: f32 = 1e-4;

fn conformance(m: Manifest) -> BackendConformance {
    BackendConformance::new(move || {
        let client = thread_client().unwrap();
        Box::new(ModelRuntime::load(&client, &m, "tiny-2m", None).unwrap())
    })
    .with_tolerance(XLA_TOL)
}

#[test]
fn load_reports_compiled_shapes() {
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let rt = ModelRuntime::load(&client, &m, "tiny-2m", None).unwrap();
    assert_eq!(ModelRuntime::compiled_chunks(&rt), vec![16, 32, 64, 128]);
    assert_eq!(ModelRuntime::compiled_batches(&rt), vec![1, 2, 4]);
    assert!(rt.load_seconds > 0.0);
}

#[test]
fn load_subset_restricts_compilation() {
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let rt =
        ModelRuntime::load_subset(&client, &m, "tiny-2m", None, Some(&[16]), Some(&[1])).unwrap();
    assert_eq!(ModelRuntime::compiled_chunks(&rt), vec![16]);
    assert_eq!(ModelRuntime::compiled_batches(&rt), vec![1]);
}

#[test]
fn xla_backend_passes_shared_conformance_suite() {
    // One test running every shared check: model loads dominate the
    // runtime here, so the factory-per-check granularity the reference
    // suite uses would recompile executables eight times over.
    let Some(m) = manifest() else { return };
    conformance(m).run_all();
}

#[test]
fn dispatches_and_exec_time_reported() {
    let Some(m) = manifest() else { return };
    let client = thread_client().unwrap();
    let mut rt = ModelRuntime::load_subset(&client, &m, "tiny-2m", None, Some(&[16]), Some(&[1]))
        .unwrap();
    let mp = rt.config().max_pages_per_seq();
    let mut bt = vec![0i32; mp];
    bt[0] = 1;
    let mut ids = vec![0i32; 16];
    ids[0] = 3;
    let out = rt.prefill(&ids, 1, &bt).unwrap();
    // 2 layers x 11 + 3
    assert_eq!(out.dispatches, 25);
    assert!(out.exec_seconds > 0.0);
}
