//! Browser-mode integration: the cost model must slow things down without
//! changing any observable output (same tokens, same usage counts).

use webllm::api::ChatCompletionRequest;
use webllm::browser::BrowserConfig;
use webllm::coordinator::{EngineConfig, MLCEngine};

fn have_artifacts() -> bool {
    webllm::artifacts_dir().join("manifest.json").exists()
}

fn req() -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new("tiny-2m").user("browser parity test");
    r.max_tokens = 10;
    r.sampling.temperature = 0.0;
    r
}

#[test]
fn browser_mode_is_output_transparent() {
    if !have_artifacts() {
        return;
    }
    let mut native = MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).unwrap();
    let mut browser = MLCEngine::new(&EngineConfig::browser(&["tiny-2m"])).unwrap();
    let a = native.chat_completion(req()).unwrap();
    let b = browser.chat_completion(req()).unwrap();
    assert_eq!(a.text(), b.text(), "cost model must not change outputs");
    assert_eq!(a.usage.prompt_tokens, b.usage.prompt_tokens);
    assert_eq!(a.usage.completion_tokens, b.usage.completion_tokens);
}

#[test]
fn browser_mode_is_slower_and_accounted() {
    if !have_artifacts() {
        return;
    }
    // Exaggerated overheads so the delta is unambiguous at tiny scale.
    let mut cfg = EngineConfig::browser(&["tiny-2m"]);
    cfg.browser = Some(BrowserConfig {
        dispatch_overhead_us: 200.0,
        bandwidth_tax_us_per_mb: 10_000.0,
        wasm_slowdown: 2.0,
    });
    let mut native = MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).unwrap();
    let mut browser = MLCEngine::new(&cfg).unwrap();
    native.chat_completion(req()).unwrap(); // warm
    browser.chat_completion(req()).unwrap();
    let a = native.chat_completion(req()).unwrap();
    let b = browser.chat_completion(req()).unwrap();
    assert!(
        b.usage.decode_tokens_per_s < a.usage.decode_tokens_per_s,
        "browser {} >= native {}",
        b.usage.decode_tokens_per_s,
        a.usage.decode_tokens_per_s
    );
}

#[test]
fn default_config_retention_is_plausible_for_tiny() {
    if !have_artifacts() {
        return;
    }
    // tiny-2m steps are so fast (~5ms) that even small absolute overhead
    // is a large fraction; just require a sane, non-degenerate ratio.
    let mut native = MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).unwrap();
    let mut browser = MLCEngine::new(&EngineConfig::browser(&["tiny-2m"])).unwrap();
    native.chat_completion(req()).unwrap();
    browser.chat_completion(req()).unwrap();
    let a = native.chat_completion(req()).unwrap();
    let b = browser.chat_completion(req()).unwrap();
    let retention = b.usage.decode_tokens_per_s / a.usage.decode_tokens_per_s;
    assert!(retention > 0.2 && retention <= 1.5, "retention {retention}");
}
