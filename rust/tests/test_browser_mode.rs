//! Browser-mode integration: the cost model must slow things down without
//! changing any observable output (same tokens, same usage counts).
//!
//! Runs unconditionally on the deterministic reference backend — the
//! cost model is backend-agnostic (dispatch counts + weight traffic +
//! WASM CPU stages), so its transparency and slowdown are fully
//! checkable without artifacts.

use webllm::api::ChatCompletionRequest;
use webllm::browser::BrowserConfig;
use webllm::coordinator::{EngineConfig, MLCEngine, ServiceWorkerMLCEngine};

const MODEL: &str = "tiny-ref";

fn req() -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new(MODEL).user("browser parity test");
    // 24 decode steps: the injected per-step overhead (>1ms even at
    // default calibration) accumulates far past scheduler noise.
    r.max_tokens = 24;
    r.sampling.temperature = 0.0;
    // Pin the token count so the two modes do identical work.
    r.sampling.logit_bias.insert(2, -100.0); // <eos>
    r.sampling.logit_bias.insert(7, -100.0); // <|end|>
    r
}

#[test]
fn browser_mode_is_output_transparent() {
    let mut native = MLCEngine::new(&EngineConfig::reference(&[MODEL])).unwrap();
    let mut browser = MLCEngine::new(&EngineConfig::reference_browser(&[MODEL])).unwrap();
    let a = native.chat_completion(req()).unwrap();
    let b = browser.chat_completion(req()).unwrap();
    assert_eq!(a.text(), b.text(), "cost model must not change outputs");
    assert_eq!(a.usage.prompt_tokens, b.usage.prompt_tokens);
    assert_eq!(a.usage.completion_tokens, b.usage.completion_tokens);
    assert_eq!(a.choices[0].finish_reason, b.choices[0].finish_reason);
}

#[test]
fn browser_mode_is_slower_and_accounted() {
    // Exaggerated overheads so the delta is unambiguous at tiny scale.
    let mut cfg = EngineConfig::reference_browser(&[MODEL]);
    cfg.browser = Some(BrowserConfig {
        dispatch_overhead_us: 200.0,
        bandwidth_tax_us_per_mb: 10_000.0,
        wasm_slowdown: 2.0,
    });
    let mut native = MLCEngine::new(&EngineConfig::reference(&[MODEL])).unwrap();
    let mut browser = MLCEngine::new(&cfg).unwrap();
    native.chat_completion(req()).unwrap(); // warm
    browser.chat_completion(req()).unwrap();
    let a = native.chat_completion(req()).unwrap();
    let b = browser.chat_completion(req()).unwrap();
    assert!(
        b.usage.decode_tokens_per_s < a.usage.decode_tokens_per_s,
        "browser {} >= native {}",
        b.usage.decode_tokens_per_s,
        a.usage.decode_tokens_per_s
    );
    assert!(b.usage.e2e_s > a.usage.e2e_s);
}

#[test]
fn default_config_is_still_slower() {
    // Even the default (calibrated) overheads inject >1ms per decode step
    // at tiny-ref's weight footprint, dwarfing the reference backend's
    // microsecond steps.
    let mut native = MLCEngine::new(&EngineConfig::reference(&[MODEL])).unwrap();
    let mut browser = MLCEngine::new(&EngineConfig::reference_browser(&[MODEL])).unwrap();
    native.chat_completion(req()).unwrap();
    browser.chat_completion(req()).unwrap();
    let a = native.chat_completion(req()).unwrap();
    let b = browser.chat_completion(req()).unwrap();
    let retention = b.usage.decode_tokens_per_s / a.usage.decode_tokens_per_s;
    assert!(retention > 0.0, "retention {retention}");
    assert!(retention < 1.0, "browser mode must retain <100%: {retention}");
}

#[test]
fn browser_env_presence_tracks_config() {
    let native = MLCEngine::new(&EngineConfig::reference(&[MODEL])).unwrap();
    assert!(native.browser_env().is_none());
    let browser = MLCEngine::new(&EngineConfig::reference_browser(&[MODEL])).unwrap();
    assert!(browser.browser_env().is_some());
}

#[test]
fn browser_worker_path_is_transparent() {
    // The full frontend->worker->engine path in browser mode still
    // matches native-mode outputs byte-for-byte.
    let mut fe =
        ServiceWorkerMLCEngine::create(EngineConfig::reference_browser(&[MODEL])).unwrap();
    let over_wire = fe.chat_completion(req()).unwrap();
    let mut native = MLCEngine::new(&EngineConfig::reference(&[MODEL])).unwrap();
    let direct = native.chat_completion(req()).unwrap();
    assert_eq!(over_wire.text(), direct.text());
    assert_eq!(over_wire.usage.completion_tokens, direct.usage.completion_tokens);
}
