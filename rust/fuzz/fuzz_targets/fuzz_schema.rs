//! Fuzz the JSON-Schema compiler: any JSON document in, `Ok` with a
//! valid bounded grammar or a structured error out — never a panic.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Ok(text) = std::str::from_utf8(data) else { return };
    if text.len() > 16384 {
        return;
    }
    let Ok(schema) = webllm::json::parse(text) else { return };
    if let Ok(g) = webllm::grammar::schema_to_grammar(&schema) {
        g.validate().expect("schema_to_grammar produced an invalid grammar");
    }
    // The oracle validator must be equally panic-free on hostile schemas.
    let _ = webllm::testutil::schema_oracle::validate(&schema, &webllm::json::Value::Null);
});
