//! Fuzz the GBNF-style EBNF parser: arbitrary UTF-8 in, no panics out,
//! and any grammar it accepts must pass its own structural validation.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Ok(text) = std::str::from_utf8(data) else { return };
    if text.len() > 8192 {
        return;
    }
    if let Ok(g) = webllm::grammar::parse_ebnf(text) {
        g.validate().expect("parse_ebnf produced an invalid grammar");
    }
});
