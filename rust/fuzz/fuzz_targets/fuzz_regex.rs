//! Fuzz both regex engines (the grammar compiler and the oracle's Pike
//! VM) on the same pattern: no panics, and compiled grammars validate.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Ok(text) = std::str::from_utf8(data) else { return };
    if text.len() > 2048 {
        return;
    }
    // First half = pattern, second half = subject text (split nudged
    // back onto a char boundary).
    let mut mid = text.len() / 2;
    while !text.is_char_boundary(mid) {
        mid -= 1;
    }
    let (pat, subject) = text.split_at(mid);
    if let Ok(g) = webllm::grammar::regex_to_grammar(pat) {
        g.validate().expect("regex_to_grammar produced an invalid grammar");
    }
    let _ = webllm::testutil::schema_oracle::regex_matches(pat, subject, false);
    let _ = webllm::testutil::schema_oracle::regex_matches(pat, subject, true);
});
