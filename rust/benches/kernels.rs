//! Kernel ablation (DESIGN.md A2): the fused dequant-GEMM Pallas kernel
//! vs the unfused dequantize-then-matmul graph (§2.3's "no WebGPU kernel
//! library" problem — MLC's answer is compiler-fused kernels), plus the
//! two PagedAttention schedules.
//!
//! Each case is an AOT HLO artifact (built by aot.py) executed through
//! the same PJRT path the engine uses.

#[path = "common/mod.rs"]
mod common;

use webllm::models::Manifest;
use webllm::runtime::thread_client;
use xla::{PjRtBuffer, PjRtClient};

fn random_input(
    client: &PjRtClient,
    spec: &webllm::models::TensorSpec,
    seed: u64,
) -> PjRtBuffer {
    let n: usize = spec.shape.iter().product();
    let mut state = seed | 1;
    let mut next = || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    match spec.dtype.as_str() {
        "f32" => {
            let v: Vec<f32> = (0..n).map(|_| (next() % 2000) as f32 / 1000.0 - 1.0).collect();
            client.buffer_from_host_buffer(&v, &spec.shape, None).unwrap()
        }
        "u32" => {
            let v: Vec<u32> = (0..n).map(|_| next() as u32).collect();
            client.buffer_from_host_buffer(&v, &spec.shape, None).unwrap()
        }
        "i32" => {
            // valid page ids / seq lens: small positive ints
            let v: Vec<i32> = (0..n).map(|_| (next() % 64 + 1) as i32).collect();
            client.buffer_from_host_buffer(&v, &spec.shape, None).unwrap()
        }
        other => panic!("dtype {other}"),
    }
}

fn main() {
    let manifest = Manifest::load(&webllm::artifacts_dir()).expect("artifacts");
    let client = thread_client().expect("client");
    let n = common::iters(50, 5);

    common::print_header("kernel ablations (AOT HLO via PJRT, CPU)");
    let mut pairs: Vec<(String, f64)> = Vec::new();
    for (name, entry) in &manifest.kernel_bench {
        let proto = xla::HloModuleProto::from_text_file(&entry.path).expect("parse hlo");
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).expect("compile");
        let inputs: Vec<PjRtBuffer> = entry
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| random_input(&client, s, 0x9E37 + i as u64))
            .collect();
        let refs: Vec<&PjRtBuffer> = inputs.iter().collect();
        let r = common::time_it(name, 3, n, || {
            let out = exe.execute_b(&refs).unwrap();
            std::hint::black_box(&out);
        });
        pairs.push((name.clone(), r.mean_ms));
        common::print_result(&r);
    }

    // Fused-vs-unfused summary.
    println!("\nfused dequant-GEMM vs unfused (mean speedup):");
    let lookup = |n: &str| pairs.iter().find(|(k, _)| k == n).map(|(_, v)| *v);
    for shape in ["llama_qkv", "llama_ffn", "llama_head", "phi_ffn"] {
        if let (Some(f), Some(u)) =
            (lookup(&format!("q4_{shape}_fused")), lookup(&format!("q4_{shape}_unfused")))
        {
            println!("  {shape:<14} fused {f:>8.3} ms | unfused {u:>8.3} ms | ratio {:.2}x", u / f);
        }
    }
    if let (Some(l), Some(g)) =
        (lookup("paged_attention_paged_loop"), lookup("paged_attention_gather"))
    {
        println!("paged attention: loop {l:.3} ms | gather {g:.3} ms | gather speedup {:.1}x (CPU backend specialization)", l / g);
    }
}
