//! Synthetic load harness (DESIGN.md §2.3): a seeded, deterministic
//! arrival trace of mixed-length prompts across three priority classes,
//! with a warm-prefix share (returning "sessions" reusing one system
//! prompt), driven against a deliberately small KV pool so preemption
//! and back-pressure actually fire. Reference backend only — runs
//! everywhere with no artifacts, so it doubles as the CI perf smoke for
//! the preemption/priority scheduler.
//!
//! Reports throughput, TTFT p50/p95 (overall and for the interactive
//! class), ITL p99, preemptions, recomputed tokens, and queue-full
//! rejections. Then replays the *same* trace under a seeded fault
//! schedule (transients, NaN rows, stalls, one mid-trace device loss)
//! and reports goodput plus the recovery tax — the wall-clock premium
//! the engine pays to absorb the faults. Writes ../BENCH_load.json
//! (repo root).

#[path = "common/mod.rs"]
mod common;

use std::collections::HashMap;
use std::time::Instant;
use webllm::api::ChatCompletionRequest;
use webllm::coordinator::{EngineConfig, EngineEvent, MLCEngine};
use webllm::json::Value;
use webllm::metrics::Histogram;
use webllm::runtime::{FaultKind, FaultPlan};

const MODEL: &str = "tiny-ref";
/// Shared leading content for the warm-prefix share: identical leading
/// tokens land on identical pages, so returning sessions hit the prefix
/// cache instead of re-prefilling.
const SESSION_PREFIX: &str = "you are a helpful session assistant"; // 35 chars

/// One generated request: everything needed to rebuild it on arrival.
struct Spec {
    content: String,
    priority: i32,
    max_tokens: usize,
    /// Engine step at which this request arrives.
    arrival: usize,
}

/// Splitmix-style LCG; good enough for a reproducible trace.
fn next(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

/// Uniform draw in (0, 1] from the LCG's top 31 bits.
fn unit(state: &mut u64) -> f64 {
    ((next(state) & 0x7FFF_FFFF) as f64 + 1.0) / 2_147_483_649.0
}

/// Deterministic request mix shared by every trace shape: ~50% short /
/// 35% medium / 15% long prompts, 40% warm-prefix share, priorities 2
/// (interactive) / 0 / -1 (batch). `gap` yields the arrival spacing (in
/// engine steps) before request `i`.
fn mixed_specs(n: usize, seed: u64, mut gap: impl FnMut(&mut u64, usize) -> usize) -> Vec<Spec> {
    let mut s = seed;
    let mut at = 0usize;
    (0..n)
        .map(|i| {
            let len_roll = next(&mut s) % 100;
            let body_len = if len_roll < 50 {
                8
            } else if len_roll < 85 {
                40
            } else {
                72
            };
            let warm = next(&mut s) % 100 < 40;
            // A distinct 2-digit tag keeps cold prompts out of the
            // prefix cache; warm ones share SESSION_PREFIX pages.
            let mut content = String::new();
            if warm {
                content.push_str(SESSION_PREFIX);
                content.push(' ');
            }
            content.push_str(&format!("{:02}{}", i % 100, "x".repeat(body_len)));
            let prio_roll = next(&mut s) % 100;
            let priority = if prio_roll < 20 {
                2
            } else if prio_roll < 85 {
                0
            } else {
                -1
            };
            let max_tokens = 2 + (next(&mut s) % 14) as usize;
            at += gap(&mut s, i);
            Spec { content, priority, max_tokens, arrival: at }
        })
        .collect()
}

/// The headline trace: bursty arrivals, 0-2 steps between requests.
fn trace(n: usize, seed: u64) -> Vec<Spec> {
    mixed_specs(n, seed, |s, _| (next(s) % 3) as usize)
}

/// Open-loop Poisson arrivals at `rate` requests per engine step:
/// exponential inter-arrival times, independent of service progress.
fn poisson_trace(n: usize, seed: u64, rate: f64) -> Vec<Spec> {
    mixed_specs(n, seed, |s, _| (-unit(s).ln() / rate).round() as usize)
}

/// Same mean `rate`, but arrivals land in back-to-back bursts of
/// `burst`: one exponential gap per burst, zero spacing inside it.
fn bursty_trace(n: usize, seed: u64, rate: f64, burst: usize) -> Vec<Spec> {
    mixed_specs(n, seed, |s, i| {
        if i % burst == 0 {
            (-unit(s).ln() * burst as f64 / rate).round() as usize
        } else {
            0
        }
    })
}

fn build(spec: &Spec) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new(MODEL).user(spec.content.clone());
    r.max_tokens = spec.max_tokens;
    r.sampling.temperature = 0.0;
    r.stream = true;
    r.priority = spec.priority;
    webllm::testutil::ban_reference_invisible(&mut r);
    r
}

/// Everything one replay of the trace produces.
struct RunOut {
    wall: f64,
    steps: usize,
    tokens: usize,
    completed: usize,
    failed: usize,
    rejected: u64,
    ttft: Histogram,
    ttft_hi: Histogram,
    itl: Histogram,
    e2e: Histogram,
    stats: Value,
}

/// Drive the full trace to idle on a fresh engine, optionally under a
/// fault schedule. `step()` must stay `Ok` either way — recoverable
/// faults are the engine's problem, not the driver's. With `open_loop`
/// a queue-full rejection *drops* the request (arrivals never wait on
/// service, the saturation-sweep contract); otherwise the driver
/// retries it next step, like a client honoring Retry-After.
fn run_trace(
    specs: &[Spec],
    plan: Option<FaultPlan>,
    prefix_cache: bool,
    open_loop: bool,
) -> RunOut {
    // Small waiting room so bursts exercise QueueFull back-pressure;
    // everything else is the production default (adaptive prefill on,
    // 4 concurrent prefills) over the tiny 64-page reference pool.
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.max_waiting_requests = 8;
    cfg.fault_plan = plan;
    cfg.enable_prefix_cache = prefix_cache;
    let mut engine = MLCEngine::new(&cfg).expect("reference engine");

    let mut prio_of: HashMap<u64, i32> = HashMap::new();
    let mut last_chunk: HashMap<u64, Instant> = HashMap::new();
    let mut out = RunOut {
        wall: 0.0,
        steps: 0,
        tokens: 0,
        completed: 0,
        failed: 0,
        rejected: 0,
        ttft: Histogram::new(),
        ttft_hi: Histogram::new(),
        itl: Histogram::new(),
        e2e: Histogram::new(),
        stats: Value::Null,
    };

    let t0 = Instant::now();
    let mut next_req = 0usize;
    let mut step_no = 0usize;
    while next_req < specs.len() || engine.has_work() {
        // Arrivals due this step; a QueueFull rejection re-tries the
        // same request next step (what a client with Retry-After does).
        while next_req < specs.len() && specs[next_req].arrival <= step_no {
            match engine.submit(build(&specs[next_req])) {
                Ok(id) => {
                    prio_of.insert(id, specs[next_req].priority);
                    next_req += 1;
                }
                Err(e) if e.kind == "queue_full" => {
                    out.rejected += 1;
                    if open_loop {
                        // Open loop: the arrival is lost, not deferred.
                        next_req += 1;
                        continue;
                    }
                    break;
                }
                Err(e) => panic!("submit failed: {e:?}"),
            }
        }
        engine.step().expect("engine step");
        step_no += 1;
        let now = Instant::now();
        for ev in engine.poll_events() {
            match ev {
                EngineEvent::Chunk(rid, c) if !c.delta.is_empty() => {
                    if let Some(prev) = last_chunk.insert(rid, now) {
                        out.itl.push((now - prev).as_secs_f64() * 1e3);
                    }
                }
                EngineEvent::Done(rid, resp) => {
                    out.completed += 1;
                    out.tokens += resp.usage.completion_tokens;
                    out.ttft.push(resp.usage.ttft_s * 1e3);
                    if prio_of.get(&rid) == Some(&2) {
                        out.ttft_hi.push(resp.usage.ttft_s * 1e3);
                    }
                    out.e2e.push(resp.usage.e2e_s * 1e3);
                    last_chunk.remove(&rid);
                }
                EngineEvent::Error(rid, e) => {
                    // Under the fault schedule, data-plane corruption is
                    // allowed to fail the implicated request — anything
                    // else would be a recovery bug.
                    assert_eq!(e.kind, "data_plane_error", "unexpected failure: {e}");
                    out.failed += 1;
                    last_chunk.remove(&rid);
                }
                _ => {}
            }
        }
    }
    out.wall = t0.elapsed().as_secs_f64();
    out.steps = step_no;
    out.stats = engine.stats_json();
    out
}

fn stat(stats: &Value, k: &str) -> i64 {
    stats.get(k).and_then(|v| v.as_i64()).unwrap_or(0)
}

/// n=4 parallel sampling vs four independent copies of every prompt,
/// prefix cache off so each prefill token is honestly paid: forking must
/// collapse the family's prompt compute to a single pass, sharing full
/// prompt pages and CoW-copying only partial tails.
fn fork_section(n_prompts: usize) -> (i64, Value) {
    let run = |n_choices: usize, copies: usize| {
        let mut cfg = EngineConfig::reference(&[MODEL]);
        cfg.enable_prefix_cache = false;
        let mut engine = MLCEngine::new(&cfg).expect("reference engine");
        let mut tokens = 0usize;
        let t0 = Instant::now();
        for i in 0..n_prompts {
            for _ in 0..copies {
                let mut r = ChatCompletionRequest::new(MODEL)
                    .user(format!("{SESSION_PREFIX} fork {i:02} {}", "x".repeat(37 + i % 8)));
                r.max_tokens = 8;
                r.sampling.temperature = 0.7;
                r.sampling.seed = Some(0xF00D + i as u64);
                webllm::testutil::ban_reference_invisible(&mut r);
                let resp = engine.chat_completion(r.with_n(n_choices)).expect("completion");
                tokens += resp.usage.completion_tokens;
            }
        }
        (tokens, t0.elapsed().as_secs_f64(), engine.stats_json())
    };

    let (tok_fork, wall_fork, forked) = run(4, 1);
    let (tok_solo, wall_solo, nofork) = run(1, 4);
    let prefill_forked = stat(&forked, "prefill_tokens");
    let prefill_nofork = stat(&nofork, "prefill_tokens");
    let saved = prefill_nofork - prefill_forked;
    println!(
        "n=4 forked   : {prefill_forked:>5} prefill tok | {tok_fork:>4} completion tok | \
         forks {} | cow copies {} | shared pages {} | {:.1} ms",
        stat(&forked, "forks"),
        stat(&forked, "cow_page_copies"),
        stat(&forked, "shared_pages"),
        wall_fork * 1e3,
    );
    println!(
        "4x independent: {prefill_nofork:>5} prefill tok | {tok_solo:>4} completion tok | \
         {:.1} ms",
        wall_solo * 1e3,
    );
    println!(
        "prefill tokens saved by forking: {saved} ({:.0}% of the no-fork bill)",
        100.0 * saved as f64 / prefill_nofork.max(1) as f64,
    );
    assert!(stat(&forked, "forks") > 0, "n=4 requests must fork");
    assert!(stat(&forked, "cow_page_copies") > 0, "partial tail pages must be CoW-copied");
    assert!(
        prefill_forked < prefill_nofork,
        "forking must cut prefill compute: {prefill_forked} vs {prefill_nofork}"
    );
    let report = webllm::obj! {
        "description" => "identical prompts served as one n=4 request vs four independent \
                          n=1 requests, prefix cache disabled; prefill tokens saved is the \
                          prompt compute the fork avoids",
        "n_prompts" => n_prompts as i64,
        "prefill_tokens_forked" => prefill_forked,
        "prefill_tokens_nofork" => prefill_nofork,
        "prefill_tokens_saved" => saved,
        "completion_tokens_forked" => tok_fork as i64,
        "completion_tokens_nofork" => tok_solo as i64,
        "forks" => stat(&forked, "forks"),
        "cow_page_copies" => stat(&forked, "cow_page_copies"),
        "shared_pages_high_water" => stat(&forked, "shared_pages"),
        "wall_ms_forked" => wall_fork * 1e3,
        "wall_ms_nofork" => wall_solo * 1e3,
    };
    (saved, report)
}

fn fault_stat(stats: &Value, k: &str) -> i64 {
    stats.get("faults").and_then(|f| f.get(k)).and_then(|v| v.as_i64()).unwrap_or(0)
}

fn main() {
    let n = common::iters(160, 32);
    let specs = trace(n, 0xC0FFEE);
    let longs = specs
        .iter()
        .filter(|s| s.content.bytes().filter(|&b| b == b'x').count() >= 72)
        .count();
    let interactive = specs.iter().filter(|s| s.priority == 2).count();
    println!(
        "=== synthetic load: {n} requests ({longs} long, {interactive} interactive) \
         on {MODEL}, 64-page pool ==="
    );

    let clean = run_trace(&specs, None, true, false);
    assert_eq!(clean.completed, n, "every request must finish");
    assert_eq!(clean.failed, 0, "nothing may fail without a fault plan");
    let preemptions = stat(&clean.stats, "preemptions");
    let recomputed = stat(&clean.stats, "preempted_tokens_recomputed");
    let per_model = |k: &str| {
        clean
            .stats
            .get("models")
            .and_then(|m| m.get(MODEL))
            .and_then(|m| m.get(k))
            .and_then(|v| v.as_i64())
            .unwrap_or(0)
    };
    println!(
        "wall {:>6.3}s | {:.0} tok/s | ttft p50 {:.3} ms (interactive {:.3}) | \
         itl p99 {:.4} ms",
        clean.wall,
        clean.tokens as f64 / clean.wall,
        clean.ttft.percentile(50.0),
        clean.ttft_hi.percentile(50.0),
        clean.itl.percentile(99.0),
    );
    println!(
        "preemptions {preemptions} | recomputed {recomputed} tok | \
         queue-full rejections {} | prefix hits {} / misses {}",
        clean.rejected,
        per_model("prefix_cache_hits"),
        per_model("prefix_cache_misses"),
    );

    // Same trace, hostile substrate: ~2% of backend ops fault (transient
    // / NaN row / 1-3ms stall, seeded) plus one guaranteed device loss
    // mid-trace. Goodput counts only tokens of requests that completed;
    // the recovery tax is the wall-clock premium over the clean run.
    let plan = FaultPlan::seeded(0xFA17, 4000, 2).then(400, FaultKind::DeviceLost);
    let faults_scheduled = plan.len();
    println!(
        "\n=== same trace under faults: {faults_scheduled} scheduled \
         (seeded 2% + 1 device loss) ==="
    );
    let faulty = run_trace(&specs, Some(plan), true, false);
    assert_eq!(faulty.completed + faulty.failed, n, "every request must terminate");
    assert!(
        fault_stat(&faulty.stats, "device_resets") >= 1,
        "the scheduled device loss must have fired"
    );
    let goodput = faulty.tokens as f64 / faulty.wall;
    let recovery_tax_pct = (faulty.wall - clean.wall) / clean.wall * 100.0;
    println!(
        "wall {:>6.3}s | goodput {:.0} tok/s | completed {} / failed {} | \
         recovery tax {:+.1}%",
        faulty.wall, goodput, faulty.completed, faulty.failed, recovery_tax_pct,
    );
    println!(
        "faults injected {} | transient retries {} | device resets {} | \
         preemptions {}",
        fault_stat(&faulty.stats, "faults_injected"),
        fault_stat(&faulty.stats, "transient_retries"),
        fault_stat(&faulty.stats, "device_resets"),
        stat(&faulty.stats, "preemptions"),
    );

    // Preemption-aware retention: replay the headline trace with the
    // prefix cache disabled. Eviction then surrenders every computed
    // token instead of only partial tail pages, so retention must show
    // up as a strictly smaller recompute bill on resume.
    println!("\n=== same trace, prefix cache disabled (retention off) ===");
    let bare = run_trace(&specs, None, false, false);
    let recomputed_bare = stat(&bare.stats, "preempted_tokens_recomputed");
    assert!(stat(&bare.stats, "preemptions") > 0, "retention-off run must still preempt");
    println!(
        "recomputed on resume: {recomputed} tok with retention vs {recomputed_bare} without \
         ({} preemptions vs {})",
        preemptions,
        stat(&bare.stats, "preemptions"),
    );
    assert!(
        recomputed < recomputed_bare,
        "prefix-cache retention must cut preemption recompute: \
         {recomputed} with vs {recomputed_bare} without"
    );

    // Open-loop arrival sweep: Poisson and bursty processes at rising
    // offered rates over the same request mix. Delivered rate tracks
    // offered until the pool and waiting room saturate; the knee is the
    // first rate where the engine sheds load (rejections) or falls
    // behind (delivered < 75% of offered).
    let sweep_n = common::iters(64, 24);
    let rates = [0.125, 0.25, 0.5, 1.0, 2.0];
    let mut sweep_rows: Vec<Value> = Vec::new();
    let mut knee_of: HashMap<&str, f64> = HashMap::new();
    println!("\n=== open-loop QPS sweep ({sweep_n} requests per point) ===");
    for process in ["poisson", "bursty"] {
        for &rate in &rates {
            let sp = match process {
                "poisson" => poisson_trace(sweep_n, 0xA11CE, rate),
                _ => bursty_trace(sweep_n, 0xA11CE, rate, 4),
            };
            let out = run_trace(&sp, None, true, true);
            let delivered = out.completed as f64 / out.steps.max(1) as f64;
            let saturated = out.rejected > 0 || delivered < 0.75 * rate;
            if saturated {
                knee_of.entry(process).or_insert(rate);
            }
            println!(
                "{process:<8} offered {rate:>5.3} req/step | delivered {delivered:>5.3} | \
                 dropped {:>2} | ttft p95 {:>7.3} ms{}",
                out.rejected,
                out.ttft.percentile(95.0),
                if saturated { "  <- saturated" } else { "" },
            );
            sweep_rows.push(webllm::obj! {
                "process" => process,
                "offered_req_per_step" => rate,
                "delivered_req_per_step" => delivered,
                "completed" => out.completed as i64,
                "dropped" => out.rejected as i64,
                "steps" => out.steps as i64,
                "ttft_p95_ms" => out.ttft.percentile(95.0),
                "saturated" => saturated,
            });
        }
    }
    for process in ["poisson", "bursty"] {
        let knee = knee_of.get(process);
        assert!(knee.is_some(), "{process} sweep never saturated; raise the rate ceiling");
        println!("{process} saturation knee: {} req/step", knee.unwrap());
    }

    // n=4 parallel sampling: prefill once, decode four branches.
    println!("\n=== n=4 parallel sampling via CoW forking ===");
    let (prefill_saved, fork_report) = fork_section(common::iters(12, 4));

    let report = webllm::obj! {
        "bench" => "load",
        "generated_by" => "cargo bench --bench load",
        "label" => "measured",
        "quick_mode" => common::quick(),
        "scenario" => webllm::obj! {
            "description" => "seeded deterministic arrival trace, mixed prompt lengths \
                              (50/35/15 short/medium/long), 40% warm-prefix share, \
                              priorities 2/0/-1, 64-page reference pool, waiting cap 8",
            "backend" => "reference (seeded-deterministic, native mode)",
            "requests" => n as i64,
            "long_prompts" => longs as i64,
            "interactive_requests" => interactive as i64,
            "seed" => 0xC0FFEEi64,
        },
        "completed" => clean.completed as i64,
        "completion_tokens" => clean.tokens as i64,
        "wall_seconds" => clean.wall,
        "throughput_tok_s" => clean.tokens as f64 / clean.wall,
        "ttft_p50_ms" => clean.ttft.percentile(50.0),
        "ttft_p95_ms" => clean.ttft.percentile(95.0),
        "ttft_interactive_p50_ms" => clean.ttft_hi.percentile(50.0),
        "ttft_interactive_p95_ms" => clean.ttft_hi.percentile(95.0),
        "itl_p99_ms" => clean.itl.percentile(99.0),
        "e2e_p50_ms" => clean.e2e.percentile(50.0),
        "preemptions" => preemptions,
        "preempted_tokens_recomputed" => recomputed,
        "queue_full_rejections" => clean.rejected as i64,
        "prefix_cache_hits" => per_model("prefix_cache_hits"),
        "prefix_cache_misses" => per_model("prefix_cache_misses"),
        "retention" => webllm::obj! {
            "description" => "headline trace replayed with the prefix cache disabled: \
                              without retention every preempted token is recomputed on \
                              resume, with it only partial tail pages are",
            "preempted_tokens_recomputed_with_retention" => recomputed,
            "preempted_tokens_recomputed_without" => recomputed_bare,
            "preemptions_with_retention" => preemptions,
            "preemptions_without" => stat(&bare.stats, "preemptions"),
        },
        "arrival_sweep" => Value::Array(sweep_rows),
        "saturation_knee" => webllm::obj! {
            "poisson_req_per_step" => *knee_of.get("poisson").unwrap(),
            "bursty_req_per_step" => *knee_of.get("bursty").unwrap(),
        },
        "fork" => fork_report,
        "fork_prefill_tokens_saved" => prefill_saved,
        "faulty" => webllm::obj! {
            "description" => "identical trace replayed under a seeded fault schedule: \
                              ~2% of backend ops fault (transient / NaN row / 1-3ms \
                              stall, seed 0xFA17 over 4000 ops) plus one device loss \
                              at op 400",
            "faults_scheduled" => faults_scheduled as i64,
            "completed" => faulty.completed as i64,
            "failed" => faulty.failed as i64,
            "completion_tokens" => faulty.tokens as i64,
            "wall_seconds" => faulty.wall,
            "goodput_tok_s" => goodput,
            "recovery_tax_pct" => recovery_tax_pct,
            "ttft_p50_ms" => faulty.ttft.percentile(50.0),
            "ttft_p95_ms" => faulty.ttft.percentile(95.0),
            "itl_p99_ms" => faulty.itl.percentile(99.0),
            "faults_injected" => fault_stat(&faulty.stats, "faults_injected"),
            "transient_retries" => fault_stat(&faulty.stats, "transient_retries"),
            "device_resets" => fault_stat(&faulty.stats, "device_resets"),
            "watchdog_stalls" => fault_stat(&faulty.stats, "watchdog_stalls"),
            "requests_failed" => fault_stat(&faulty.stats, "requests_failed"),
            "preemptions" => stat(&faulty.stats, "preemptions"),
        },
    };
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_load.json");
    match std::fs::write(&path, webllm::json::to_string_pretty(&report) + "\n") {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", path.display()),
    }

    // The trace is engineered to overcommit the 64-page pool; zero
    // preemptions means the scheduler stopped feeling memory pressure
    // (or stopped preempting), which is exactly what this smoke exists
    // to catch. Asserted after the report is written so a failing run
    // still leaves its numbers behind.
    assert!(preemptions > 0, "load trace must trigger preemption");
}
