//! Synthetic load harness (DESIGN.md §2.3): a seeded, deterministic
//! arrival trace of mixed-length prompts across three priority classes,
//! with a warm-prefix share (returning "sessions" reusing one system
//! prompt), driven against a deliberately small KV pool so preemption
//! and back-pressure actually fire. Reference backend only — runs
//! everywhere with no artifacts, so it doubles as the CI perf smoke for
//! the preemption/priority scheduler.
//!
//! Reports throughput, TTFT p50/p95 (overall and for the interactive
//! class), ITL p99, preemptions, recomputed tokens, and queue-full
//! rejections. Then replays the *same* trace under a seeded fault
//! schedule (transients, NaN rows, stalls, one mid-trace device loss)
//! and reports goodput plus the recovery tax — the wall-clock premium
//! the engine pays to absorb the faults. Writes ../BENCH_load.json
//! (repo root).

#[path = "common/mod.rs"]
mod common;

use std::collections::HashMap;
use std::time::Instant;
use webllm::api::ChatCompletionRequest;
use webllm::coordinator::{EngineConfig, EngineEvent, MLCEngine};
use webllm::json::Value;
use webllm::metrics::Histogram;
use webllm::runtime::{FaultKind, FaultPlan};

const MODEL: &str = "tiny-ref";
/// Shared leading content for the warm-prefix share: identical leading
/// tokens land on identical pages, so returning sessions hit the prefix
/// cache instead of re-prefilling.
const SESSION_PREFIX: &str = "you are a helpful session assistant"; // 35 chars

/// One generated request: everything needed to rebuild it on arrival.
struct Spec {
    content: String,
    priority: i32,
    max_tokens: usize,
    /// Engine step at which this request arrives.
    arrival: usize,
}

/// Splitmix-style LCG; good enough for a reproducible trace.
fn next(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

/// Deterministic trace: ~50% short / 35% medium / 15% long prompts,
/// 40% warm-prefix share, priorities 2 (interactive) / 0 / -1 (batch),
/// bursty arrivals (0-2 steps between consecutive requests).
fn trace(n: usize, seed: u64) -> Vec<Spec> {
    let mut s = seed;
    let mut at = 0usize;
    (0..n)
        .map(|i| {
            let len_roll = next(&mut s) % 100;
            let body_len = if len_roll < 50 {
                8
            } else if len_roll < 85 {
                40
            } else {
                72
            };
            let warm = next(&mut s) % 100 < 40;
            // A distinct 2-digit tag keeps cold prompts out of the
            // prefix cache; warm ones share SESSION_PREFIX pages.
            let mut content = String::new();
            if warm {
                content.push_str(SESSION_PREFIX);
                content.push(' ');
            }
            content.push_str(&format!("{:02}{}", i % 100, "x".repeat(body_len)));
            let prio_roll = next(&mut s) % 100;
            let priority = if prio_roll < 20 {
                2
            } else if prio_roll < 85 {
                0
            } else {
                -1
            };
            let max_tokens = 2 + (next(&mut s) % 14) as usize;
            at += (next(&mut s) % 3) as usize;
            Spec { content, priority, max_tokens, arrival: at }
        })
        .collect()
}

fn build(spec: &Spec) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new(MODEL).user(spec.content.clone());
    r.max_tokens = spec.max_tokens;
    r.sampling.temperature = 0.0;
    r.stream = true;
    r.priority = spec.priority;
    webllm::testutil::ban_reference_invisible(&mut r);
    r
}

/// Everything one replay of the trace produces.
struct RunOut {
    wall: f64,
    tokens: usize,
    completed: usize,
    failed: usize,
    rejected: u64,
    ttft: Histogram,
    ttft_hi: Histogram,
    itl: Histogram,
    e2e: Histogram,
    stats: Value,
}

/// Drive the full trace to idle on a fresh engine, optionally under a
/// fault schedule. `step()` must stay `Ok` either way — recoverable
/// faults are the engine's problem, not the driver's.
fn run_trace(specs: &[Spec], plan: Option<FaultPlan>) -> RunOut {
    // Small waiting room so bursts exercise QueueFull back-pressure;
    // everything else is the production default (adaptive prefill on,
    // 4 concurrent prefills) over the tiny 64-page reference pool.
    let mut cfg = EngineConfig::reference(&[MODEL]);
    cfg.max_waiting_requests = 8;
    cfg.fault_plan = plan;
    let mut engine = MLCEngine::new(&cfg).expect("reference engine");

    let mut prio_of: HashMap<u64, i32> = HashMap::new();
    let mut last_chunk: HashMap<u64, Instant> = HashMap::new();
    let mut out = RunOut {
        wall: 0.0,
        tokens: 0,
        completed: 0,
        failed: 0,
        rejected: 0,
        ttft: Histogram::new(),
        ttft_hi: Histogram::new(),
        itl: Histogram::new(),
        e2e: Histogram::new(),
        stats: Value::Null,
    };

    let t0 = Instant::now();
    let mut next_req = 0usize;
    let mut step_no = 0usize;
    while next_req < specs.len() || engine.has_work() {
        // Arrivals due this step; a QueueFull rejection re-tries the
        // same request next step (what a client with Retry-After does).
        while next_req < specs.len() && specs[next_req].arrival <= step_no {
            match engine.submit(build(&specs[next_req])) {
                Ok(id) => {
                    prio_of.insert(id, specs[next_req].priority);
                    next_req += 1;
                }
                Err(e) if e.kind == "queue_full" => {
                    out.rejected += 1;
                    break;
                }
                Err(e) => panic!("submit failed: {e:?}"),
            }
        }
        engine.step().expect("engine step");
        step_no += 1;
        let now = Instant::now();
        for ev in engine.poll_events() {
            match ev {
                EngineEvent::Chunk(rid, c) if !c.delta.is_empty() => {
                    if let Some(prev) = last_chunk.insert(rid, now) {
                        out.itl.push((now - prev).as_secs_f64() * 1e3);
                    }
                }
                EngineEvent::Done(rid, resp) => {
                    out.completed += 1;
                    out.tokens += resp.usage.completion_tokens;
                    out.ttft.push(resp.usage.ttft_s * 1e3);
                    if prio_of.get(&rid) == Some(&2) {
                        out.ttft_hi.push(resp.usage.ttft_s * 1e3);
                    }
                    out.e2e.push(resp.usage.e2e_s * 1e3);
                    last_chunk.remove(&rid);
                }
                EngineEvent::Error(rid, e) => {
                    // Under the fault schedule, data-plane corruption is
                    // allowed to fail the implicated request — anything
                    // else would be a recovery bug.
                    assert_eq!(e.kind, "data_plane_error", "unexpected failure: {e}");
                    out.failed += 1;
                    last_chunk.remove(&rid);
                }
                _ => {}
            }
        }
    }
    out.wall = t0.elapsed().as_secs_f64();
    out.stats = engine.stats_json();
    out
}

fn stat(stats: &Value, k: &str) -> i64 {
    stats.get(k).and_then(|v| v.as_i64()).unwrap_or(0)
}

fn fault_stat(stats: &Value, k: &str) -> i64 {
    stats.get("faults").and_then(|f| f.get(k)).and_then(|v| v.as_i64()).unwrap_or(0)
}

fn main() {
    let n = common::iters(160, 32);
    let specs = trace(n, 0xC0FFEE);
    let longs = specs
        .iter()
        .filter(|s| s.content.bytes().filter(|&b| b == b'x').count() >= 72)
        .count();
    let interactive = specs.iter().filter(|s| s.priority == 2).count();
    println!(
        "=== synthetic load: {n} requests ({longs} long, {interactive} interactive) \
         on {MODEL}, 64-page pool ==="
    );

    let clean = run_trace(&specs, None);
    assert_eq!(clean.completed, n, "every request must finish");
    assert_eq!(clean.failed, 0, "nothing may fail without a fault plan");
    let preemptions = stat(&clean.stats, "preemptions");
    let recomputed = stat(&clean.stats, "preempted_tokens_recomputed");
    let per_model = |k: &str| {
        clean
            .stats
            .get("models")
            .and_then(|m| m.get(MODEL))
            .and_then(|m| m.get(k))
            .and_then(|v| v.as_i64())
            .unwrap_or(0)
    };
    println!(
        "wall {:>6.3}s | {:.0} tok/s | ttft p50 {:.3} ms (interactive {:.3}) | \
         itl p99 {:.4} ms",
        clean.wall,
        clean.tokens as f64 / clean.wall,
        clean.ttft.percentile(50.0),
        clean.ttft_hi.percentile(50.0),
        clean.itl.percentile(99.0),
    );
    println!(
        "preemptions {preemptions} | recomputed {recomputed} tok | \
         queue-full rejections {} | prefix hits {} / misses {}",
        clean.rejected,
        per_model("prefix_cache_hits"),
        per_model("prefix_cache_misses"),
    );

    // Same trace, hostile substrate: ~2% of backend ops fault (transient
    // / NaN row / 1-3ms stall, seeded) plus one guaranteed device loss
    // mid-trace. Goodput counts only tokens of requests that completed;
    // the recovery tax is the wall-clock premium over the clean run.
    let plan = FaultPlan::seeded(0xFA17, 4000, 2).then(400, FaultKind::DeviceLost);
    let faults_scheduled = plan.len();
    println!(
        "\n=== same trace under faults: {faults_scheduled} scheduled \
         (seeded 2% + 1 device loss) ==="
    );
    let faulty = run_trace(&specs, Some(plan));
    assert_eq!(faulty.completed + faulty.failed, n, "every request must terminate");
    assert!(
        fault_stat(&faulty.stats, "device_resets") >= 1,
        "the scheduled device loss must have fired"
    );
    let goodput = faulty.tokens as f64 / faulty.wall;
    let recovery_tax_pct = (faulty.wall - clean.wall) / clean.wall * 100.0;
    println!(
        "wall {:>6.3}s | goodput {:.0} tok/s | completed {} / failed {} | \
         recovery tax {:+.1}%",
        faulty.wall, goodput, faulty.completed, faulty.failed, recovery_tax_pct,
    );
    println!(
        "faults injected {} | transient retries {} | device resets {} | \
         preemptions {}",
        fault_stat(&faulty.stats, "faults_injected"),
        fault_stat(&faulty.stats, "transient_retries"),
        fault_stat(&faulty.stats, "device_resets"),
        stat(&faulty.stats, "preemptions"),
    );

    let report = webllm::obj! {
        "bench" => "load",
        "generated_by" => "cargo bench --bench load",
        "label" => "measured",
        "quick_mode" => common::quick(),
        "scenario" => webllm::obj! {
            "description" => "seeded deterministic arrival trace, mixed prompt lengths \
                              (50/35/15 short/medium/long), 40% warm-prefix share, \
                              priorities 2/0/-1, 64-page reference pool, waiting cap 8",
            "backend" => "reference (seeded-deterministic, native mode)",
            "requests" => n as i64,
            "long_prompts" => longs as i64,
            "interactive_requests" => interactive as i64,
            "seed" => 0xC0FFEEi64,
        },
        "completed" => clean.completed as i64,
        "completion_tokens" => clean.tokens as i64,
        "wall_seconds" => clean.wall,
        "throughput_tok_s" => clean.tokens as f64 / clean.wall,
        "ttft_p50_ms" => clean.ttft.percentile(50.0),
        "ttft_p95_ms" => clean.ttft.percentile(95.0),
        "ttft_interactive_p50_ms" => clean.ttft_hi.percentile(50.0),
        "ttft_interactive_p95_ms" => clean.ttft_hi.percentile(95.0),
        "itl_p99_ms" => clean.itl.percentile(99.0),
        "e2e_p50_ms" => clean.e2e.percentile(50.0),
        "preemptions" => preemptions,
        "preempted_tokens_recomputed" => recomputed,
        "queue_full_rejections" => clean.rejected as i64,
        "prefix_cache_hits" => per_model("prefix_cache_hits"),
        "prefix_cache_misses" => per_model("prefix_cache_misses"),
        "faulty" => webllm::obj! {
            "description" => "identical trace replayed under a seeded fault schedule: \
                              ~2% of backend ops fault (transient / NaN row / 1-3ms \
                              stall, seed 0xFA17 over 4000 ops) plus one device loss \
                              at op 400",
            "faults_scheduled" => faults_scheduled as i64,
            "completed" => faulty.completed as i64,
            "failed" => faulty.failed as i64,
            "completion_tokens" => faulty.tokens as i64,
            "wall_seconds" => faulty.wall,
            "goodput_tok_s" => goodput,
            "recovery_tax_pct" => recovery_tax_pct,
            "ttft_p50_ms" => faulty.ttft.percentile(50.0),
            "ttft_p95_ms" => faulty.ttft.percentile(95.0),
            "itl_p99_ms" => faulty.itl.percentile(99.0),
            "faults_injected" => fault_stat(&faulty.stats, "faults_injected"),
            "transient_retries" => fault_stat(&faulty.stats, "transient_retries"),
            "device_resets" => fault_stat(&faulty.stats, "device_resets"),
            "watchdog_stalls" => fault_stat(&faulty.stats, "watchdog_stalls"),
            "requests_failed" => fault_stat(&faulty.stats, "requests_failed"),
            "preemptions" => stat(&faulty.stats, "preemptions"),
        },
    };
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_load.json");
    match std::fs::write(&path, webllm::json::to_string_pretty(&report) + "\n") {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", path.display()),
    }

    // The trace is engineered to overcommit the 64-page pool; zero
    // preemptions means the scheduler stopped feeling memory pressure
    // (or stopped preempting), which is exactly what this smoke exists
    // to catch. Asserted after the report is written so a failing run
    // still leaves its numbers behind.
    assert!(preemptions > 0, "load trace must trigger preemption");
}
