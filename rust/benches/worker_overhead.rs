//! Worker message-boundary overhead (DESIGN.md A1; paper §2.2).
//!
//! The paper's claim: separating the engine into a worker keeps the UI
//! responsive, and the messages are "simply OpenAI-style requests and
//! responses" — i.e. the boundary cost is serialization + a thread hop.
//! This bench measures that cost directly:
//!   1. JSON wire codec cost for a typical request/response/chunk;
//!   2. end-to-end request latency: direct engine vs worker+frontend.

#[path = "common/mod.rs"]
mod common;

use webllm::api::ChatCompletionRequest;
use webllm::coordinator::messages::{FromWorker, ToWorker};
use webllm::coordinator::{EngineConfig, MLCEngine, ServiceWorkerMLCEngine};

fn req(max_tokens: usize) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new("tiny-2m")
        .system("You are a benchmark.")
        .user("Measure the boundary, not the model.");
    r.max_tokens = max_tokens;
    r.sampling.temperature = 0.0;
    r
}

fn main() {
    let n = common::iters(2000, 100);

    // 1. Pure wire-codec cost (what every boundary crossing pays).
    common::print_header("JSON wire codec (per message)");
    let msg = ToWorker::ChatCompletion { id: 7, request: req(64) };
    let wire = msg.to_wire();
    let r = common::time_it(
        &format!("request serialize+parse ({} B)", wire.len()),
        100,
        n,
        || {
            let w = msg.to_wire();
            let back = ToWorker::from_wire(&w).unwrap();
            std::hint::black_box(&back);
        },
    );
    common::print_result(&r);
    let codec_us = r.mean_ms * 1e3;

    // 2. End-to-end: direct vs worker.
    let decode_tokens = common::iters(16, 4);
    let reps = common::iters(20, 3);

    let mut direct = MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).expect("engine");
    direct.chat_completion(req(2)).unwrap(); // warmup
    let rd = common::time_it("direct MLCEngine request", 1, reps, || {
        direct.chat_completion(req(decode_tokens)).unwrap();
    });

    let mut fe = ServiceWorkerMLCEngine::create(EngineConfig::native(&["tiny-2m"])).unwrap();
    fe.chat_completion(req(2)).unwrap();
    let rw = common::time_it("via worker + JSON channel", 1, reps, || {
        fe.chat_completion(req(decode_tokens)).unwrap();
    });

    common::print_header(&format!("end-to-end request ({decode_tokens} decode tokens)"));
    common::print_result(&rd);
    common::print_result(&rw);
    let overhead_ms = rw.mean_ms - rd.mean_ms;
    println!(
        "\nworker boundary overhead: {overhead_ms:.3} ms/request ({:.2}% of request; codec alone {codec_us:.1} us/crossing)",
        100.0 * overhead_ms / rd.mean_ms
    );
    println!("paper claim: boundary is cheap relative to inference — {}",
        if overhead_ms.abs() / rd.mean_ms < 0.1 { "OK (<10%)" } else { "CHECK" });

    // 3. Responsiveness: while the worker decodes, the frontend thread
    // stays free — measure frontend-side stall during a streaming request.
    let mut fe2 = ServiceWorkerMLCEngine::create(EngineConfig::native(&["tiny-2m"])).unwrap();
    fe2.chat_completion(req(2)).unwrap();
    let mut max_gap_ms: f64 = 0.0;
    let mut last = std::time::Instant::now();
    let mut ui_work = 0u64;
    let t0 = std::time::Instant::now();
    let _ = fe2
        .chat_completion_stream(req(common::iters(32, 6)), |_chunk| {
            max_gap_ms = max_gap_ms.max(last.elapsed().as_secs_f64() * 1e3);
            last = std::time::Instant::now();
        })
        .unwrap();
    // Simulated UI loop between chunks would have run this often:
    while t0.elapsed().as_secs_f64() < 0.001 {
        ui_work += 1;
    }
    let _ = ui_work;
    println!("max inter-chunk gap seen by 'UI' thread: {max_gap_ms:.1} ms (≈ per-token decode latency; UI thread itself never blocks on compute)");
}
