//! Scheduler ablation (DESIGN.md A5): continuous batching vs sequential
//! service, and raw decode-step scaling across compiled batch sizes.

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;
use webllm::api::ChatCompletionRequest;
use webllm::coordinator::{EngineConfig, EngineEvent, MLCEngine};
use webllm::metrics::Histogram;
use webllm::models::Manifest;
use webllm::runtime::{thread_client, ModelRuntime};

fn req(i: usize, max_tokens: usize) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new("tiny-2m").user(format!("request number {i}"));
    r.max_tokens = max_tokens;
    r.sampling.temperature = 0.0;
    r
}

fn main() {
    let n_requests = common::iters(12, 4);
    let max_tokens = common::iters(24, 6);

    // -- continuous batching vs sequential --------------------------------
    let mut engine = MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).expect("engine");
    engine.chat_completion(req(0, 2)).unwrap(); // warmup

    let t0 = Instant::now();
    let mut lat_seq = Histogram::new();
    for i in 0..n_requests {
        let t = Instant::now();
        engine.chat_completion(req(i, max_tokens)).unwrap();
        lat_seq.push(t.elapsed().as_secs_f64());
    }
    let seq_wall = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    for i in 0..n_requests {
        engine.submit(req(i, max_tokens)).unwrap();
    }
    engine.run_to_completion().unwrap();
    let mut lat_cb = Histogram::new();
    let mut tokens_cb = 0usize;
    for ev in engine.poll_events() {
        if let EngineEvent::Done(_, resp) = ev {
            lat_cb.push(resp.usage.e2e_s);
            tokens_cb += resp.usage.completion_tokens;
        }
    }
    let cb_wall = t0.elapsed().as_secs_f64();

    println!("=== continuous batching vs sequential ({n_requests} requests x {max_tokens} tokens, tiny-2m) ===");
    println!(
        "sequential : wall {seq_wall:>6.2}s | throughput {:>7.1} tok/s | p50 lat {:.2}s",
        (n_requests * max_tokens) as f64 / seq_wall,
        lat_seq.percentile(50.0)
    );
    println!(
        "continuous : wall {cb_wall:>6.2}s | throughput {:>7.1} tok/s | p50 lat {:.2}s",
        tokens_cb as f64 / cb_wall,
        lat_cb.percentile(50.0)
    );
    println!("speedup    : {:.2}x wall-clock", seq_wall / cb_wall);

    // -- raw decode-step batch scaling -------------------------------------
    let manifest = Manifest::load(&webllm::artifacts_dir()).expect("artifacts");
    let client = thread_client().unwrap();
    let mut rt = ModelRuntime::load(&client, &manifest, "tiny-2m", None).expect("runtime");
    let mc = rt.config().clone();
    let mp = mc.max_pages_per_seq();
    let reps = common::iters(40, 5);

    common::print_header("decode step vs compiled batch size (tiny-2m)");
    let mut per_token = Vec::new();
    for &b in &mc.decode_batches.clone() {
        // b fake sequences, page 1.. (content irrelevant for timing)
        let ids = vec![5i32; b];
        let positions = vec![3i32; b];
        let seq_lens = vec![4i32; b];
        let mut tables = vec![0i32; b * mp];
        for row in 0..b {
            tables[row * mp] = 1 + row as i32;
        }
        let r = common::time_it(&format!("decode b={b}"), 3, reps, || {
            rt.decode(&ids, &positions, &seq_lens, &tables).unwrap();
        });
        per_token.push((b, r.mean_ms / b as f64));
        common::print_result(&r);
    }
    println!("\nper-sequence cost (batching amortization):");
    for (b, ms) in per_token {
        println!("  b={b:<3} {ms:>8.2} ms/seq/step");
    }
}
