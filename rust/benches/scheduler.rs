//! Scheduler ablation (DESIGN.md A6): decode-stall / ITL under
//! concurrent long-prompt admission, chunked vs whole-prompt prefill
//! (reference backend, always runs — the CI perf smoke); plus the
//! original continuous-batching-vs-sequential and decode-batch-scaling
//! sections (XLA artifacts, skipped when absent).
//!
//! Writes ../BENCH_scheduler.json (repo root).

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;
use webllm::api::ChatCompletionRequest;
use webllm::coordinator::{EngineConfig, EngineEvent, MLCEngine};
use webllm::metrics::Histogram;
use webllm::models::Manifest;
use webllm::runtime::{thread_client, ModelRuntime};

fn req(i: usize, max_tokens: usize) -> ChatCompletionRequest {
    let mut r = ChatCompletionRequest::new("tiny-2m").user(format!("request number {i}"));
    r.max_tokens = max_tokens;
    r.sampling.temperature = 0.0;
    r
}

struct StallRun {
    itl: Histogram,
    ttft: Histogram,
    prefill_chunks: i64,
    decode_stall_chunks: i64,
    decode_stall_ms: f64,
}

/// One interactive decode row streams continuously while `n_admissions`
/// prompts of exactly one max-size chunk (64 tokens) are admitted.
/// Budget == 64 reproduces whole-prompt prefill (one 64-token chunk per
/// admission, the pre-chunking policy); budget == 16 slices each prompt
/// into four chunks interleaved with decode. The interactive row's
/// inter-chunk wall time *is* the decode stall.
fn reference_stall_run(budget: usize, n_admissions: usize) -> StallRun {
    let mut cfg = EngineConfig::reference(&["tiny-ref"]);
    cfg.prefill_token_budget = budget;
    // This ablation contrasts *fixed* budgets; the adaptive policy would
    // shrink chunks whenever the interactive row is decoding and blur the
    // whole-prompt-vs-chunked comparison.
    cfg.adaptive_prefill = false;
    let mut engine = MLCEngine::new(&cfg).expect("reference engine");

    // Short prompt (6 tokens) so the interactive row's own prefill is one
    // chunk under every budget and it decodes from the first step.
    let mut interactive = ChatCompletionRequest::new("tiny-ref").user("hi");
    interactive.max_tokens = 100;
    interactive.sampling.temperature = 0.0;
    interactive.stream = true;
    webllm::testutil::ban_reference_invisible(&mut interactive);
    let a_id = engine.submit(interactive).unwrap();
    engine.step().unwrap(); // prefill + first decode
    engine.poll_events();

    // 60 content chars + 4 template specials = 64 prompt tokens. A
    // distinct 2-digit prefix per prompt keeps the prefix cache out of
    // the measurement (every admission pays its full prefill).
    for i in 0..n_admissions {
        let mut r =
            ChatCompletionRequest::new("tiny-ref").user(format!("{i:02}{}", "x".repeat(58)));
        r.max_tokens = 2;
        r.sampling.temperature = 0.0;
        webllm::testutil::ban_reference_invisible(&mut r);
        engine.submit(r).unwrap();
    }

    let mut itl = Histogram::new();
    let mut ttft = Histogram::new();
    let mut done = 0usize;
    // Start the ITL clock only now: the submit loop's tokenization work
    // must not contaminate the first inter-token sample.
    let mut last_delta = Instant::now();
    while engine.has_work() && done < n_admissions {
        engine.step().unwrap();
        for ev in engine.poll_events() {
            match ev {
                EngineEvent::Chunk(rid, c) if rid == a_id && !c.delta.is_empty() => {
                    itl.push(last_delta.elapsed().as_secs_f64() * 1e3);
                    last_delta = Instant::now();
                }
                EngineEvent::Done(rid, resp) if rid != a_id => {
                    done += 1;
                    ttft.push(resp.usage.ttft_s * 1e3);
                }
                _ => {}
            }
        }
    }
    engine.abort(a_id);
    engine.run_to_completion().unwrap();
    engine.poll_events();

    let stats = engine.stats_json();
    let get = |k: &str| stats.get(k).unwrap().as_i64().unwrap();
    StallRun {
        itl,
        ttft,
        prefill_chunks: get("prefill_chunks"),
        decode_stall_chunks: get("decode_stall_chunks"),
        decode_stall_ms: stats.get("decode_stall_s").unwrap().as_f64().unwrap() * 1e3,
    }
}

fn stall_report(name: &str, budget: usize, run: &mut StallRun) -> webllm::json::Value {
    println!(
        "{name:<28} itl p50 {:>8.4} ms | p95 {:>8.4} ms | max {:>8.4} ms | \
         ttft p50 {:>8.4} ms | chunks {} (stalled {})",
        run.itl.percentile(50.0),
        run.itl.percentile(95.0),
        run.itl.percentile(100.0),
        run.ttft.percentile(50.0),
        run.prefill_chunks,
        run.decode_stall_chunks,
    );
    webllm::obj! {
        "policy" => name,
        "prefill_token_budget" => budget as i64,
        "itl_p50_ms" => run.itl.percentile(50.0),
        "itl_p95_ms" => run.itl.percentile(95.0),
        "itl_max_ms" => run.itl.percentile(100.0),
        "itl_samples" => run.itl.len() as i64,
        "ttft_p50_ms" => run.ttft.percentile(50.0),
        "prefill_chunks" => run.prefill_chunks,
        "decode_stall_chunks" => run.decode_stall_chunks,
        "decode_stall_ms_total" => run.decode_stall_ms,
    }
}

fn main() {
    // -- chunked vs whole-prompt decode stall (reference, always runs) ------
    let n_admissions = common::iters(8, 3);
    println!(
        "=== decode stall under concurrent long-prompt admission \
         (tiny-ref, {n_admissions} x 64-token prompts, 1 interactive row) ==="
    );
    // Warm up allocators/caches once so the first measured run isn't cold.
    reference_stall_run(64, 1);
    let mut whole = reference_stall_run(64, n_admissions);
    let mut chunked = reference_stall_run(16, n_admissions);
    let whole_json = stall_report("whole-prompt (budget 64)", 64, &mut whole);
    let chunked_json = stall_report("chunked (budget 16)", 16, &mut chunked);
    let p95_ratio = whole.itl.percentile(95.0) / chunked.itl.percentile(95.0).max(1e-9);
    println!("itl p95: whole-prompt / chunked = {p95_ratio:.2}x");

    let report = webllm::obj! {
        "bench" => "scheduler",
        "generated_by" => "cargo bench --bench scheduler",
        "quick_mode" => common::quick(),
        "scenario" => webllm::obj! {
            "description" => "one interactive decode row streams while N 64-token prompts \
                              are admitted; the row's inter-chunk wall time is the decode \
                              stall. whole-prompt = one 64-token chunk per admission (the \
                              pre-chunking policy); chunked = budget 16, four interleaved \
                              chunks per admission",
            "backend" => "reference (seeded-deterministic, native mode)",
            "n_admissions" => n_admissions as i64,
            "admitted_prompt_tokens" => 64,
            "interactive_max_tokens" => 100,
        },
        "decode_stall" => webllm::json::Value::Array(vec![whole_json, chunked_json]),
        "itl_p95_whole_over_chunked" => p95_ratio,
    };
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_scheduler.json");
    match std::fs::write(&path, webllm::json::to_string_pretty(&report) + "\n") {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", path.display()),
    }

    // -- XLA sections (need compiled artifacts) -----------------------------
    if !webllm::artifacts_dir().join("manifest.json").exists() {
        eprintln!(
            "SKIP: XLA artifacts not found in {} (run `make artifacts`); \
             skipping continuous-batching and batch-scaling sections",
            webllm::artifacts_dir().display()
        );
        return;
    }

    let n_requests = common::iters(12, 4);
    let max_tokens = common::iters(24, 6);

    // -- continuous batching vs sequential --------------------------------
    let mut engine = MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).expect("engine");
    engine.chat_completion(req(0, 2)).unwrap(); // warmup

    let t0 = Instant::now();
    let mut lat_seq = Histogram::new();
    for i in 0..n_requests {
        let t = Instant::now();
        engine.chat_completion(req(i, max_tokens)).unwrap();
        lat_seq.push(t.elapsed().as_secs_f64());
    }
    let seq_wall = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    for i in 0..n_requests {
        engine.submit(req(i, max_tokens)).unwrap();
    }
    engine.run_to_completion().unwrap();
    let mut lat_cb = Histogram::new();
    let mut tokens_cb = 0usize;
    for ev in engine.poll_events() {
        if let EngineEvent::Done(_, resp) = ev {
            lat_cb.push(resp.usage.e2e_s);
            tokens_cb += resp.usage.completion_tokens;
        }
    }
    let cb_wall = t0.elapsed().as_secs_f64();

    println!("=== continuous batching vs sequential ({n_requests} requests x {max_tokens} tokens, tiny-2m) ===");
    println!(
        "sequential : wall {seq_wall:>6.2}s | throughput {:>7.1} tok/s | p50 lat {:.2}s",
        (n_requests * max_tokens) as f64 / seq_wall,
        lat_seq.percentile(50.0)
    );
    println!(
        "continuous : wall {cb_wall:>6.2}s | throughput {:>7.1} tok/s | p50 lat {:.2}s",
        tokens_cb as f64 / cb_wall,
        lat_cb.percentile(50.0)
    );
    println!("speedup    : {:.2}x wall-clock", seq_wall / cb_wall);

    // -- raw decode-step batch scaling -------------------------------------
    let manifest = Manifest::load(&webllm::artifacts_dir()).expect("artifacts");
    let client = thread_client().unwrap();
    let mut rt = ModelRuntime::load(&client, &manifest, "tiny-2m", None).expect("runtime");
    let mc = rt.config().clone();
    let mp = mc.max_pages_per_seq();
    let reps = common::iters(40, 5);

    common::print_header("decode step vs compiled batch size (tiny-2m)");
    let mut per_token = Vec::new();
    for &b in &mc.decode_batches.clone() {
        // b fake sequences, page 1.. (content irrelevant for timing)
        let ids = vec![5i32; b];
        let positions = vec![3i32; b];
        let seq_lens = vec![4i32; b];
        let mut tables = vec![0i32; b * mp];
        for row in 0..b {
            tables[row * mp] = 1 + row as i32;
        }
        let r = common::time_it(&format!("decode b={b}"), 3, reps, || {
            rt.decode(&ids, &positions, &seq_lens, &tables).unwrap();
        });
        per_token.push((b, r.mean_ms / b as f64));
        common::print_result(&r);
    }
    println!("\nper-sequence cost (batching amortization):");
    for (b, ms) in per_token {
        println!("  b={b:<3} {ms:>8.2} ms/seq/step");
    }
}
