//! Shared mini-bench harness (criterion is not in the vendored set).
//!
//! Conventions: every bench binary is `harness = false`, prints a
//! uniform table, honors `WEBLLM_BENCH_QUICK=1` for a fast smoke run,
//! and exits 0 so `cargo bench` chains them.

#![allow(dead_code)]

use std::time::Instant;
use webllm::metrics::Histogram;

pub fn quick() -> bool {
    std::env::var("WEBLLM_BENCH_QUICK").map_or(false, |v| v == "1")
}

/// Pick between a full and a quick iteration count.
pub fn iters(full: usize, fast: usize) -> usize {
    if quick() {
        fast
    } else {
        full
    }
}

pub struct BenchResult {
    pub name: String,
    pub iterations: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

/// Time `f` for `n` iterations after `warmup` runs.
pub fn time_it(name: &str, warmup: usize, n: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut h = Histogram::new();
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        h.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    BenchResult {
        name: name.to_string(),
        iterations: n,
        mean_ms: h.mean(),
        p50_ms: h.percentile(50.0),
        p95_ms: h.percentile(95.0),
    }
}

pub fn print_header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12}",
        "case", "iters", "mean ms", "p50 ms", "p95 ms"
    );
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} {:>8} {:>12.3} {:>12.3} {:>12.3}",
        r.name, r.iterations, r.mean_ms, r.p50_ms, r.p95_ms
    );
}

/// A labeled throughput row (tok/s style tables).
pub fn print_tps_row(label: &str, tps: f64, extra: &str) {
    println!("{label:<44} {tps:>10.2} tok/s  {extra}");
}
