//! Shared mini-bench harness (criterion is not in the vendored set).
//!
//! Conventions: every bench binary is `harness = false`, prints a
//! uniform table, honors `WEBLLM_BENCH_QUICK=1` for a fast smoke run,
//! and exits 0 so `cargo bench` chains them.

#![allow(dead_code)]

use std::time::Instant;
use webllm::metrics::Histogram;

pub fn quick() -> bool {
    std::env::var("WEBLLM_BENCH_QUICK").map_or(false, |v| v == "1")
}

/// Pick between a full and a quick iteration count.
pub fn iters(full: usize, fast: usize) -> usize {
    if quick() {
        fast
    } else {
        full
    }
}

pub struct BenchResult {
    pub name: String,
    pub iterations: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

/// Time `f` for `n` iterations after `warmup` runs.
pub fn time_it(name: &str, warmup: usize, n: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut h = Histogram::new();
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        h.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    BenchResult {
        name: name.to_string(),
        iterations: n,
        mean_ms: h.mean(),
        p50_ms: h.percentile(50.0),
        p95_ms: h.percentile(95.0),
    }
}

pub fn print_header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12}",
        "case", "iters", "mean ms", "p50 ms", "p95 ms"
    );
}

pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} {:>8} {:>12.3} {:>12.3} {:>12.3}",
        r.name, r.iterations, r.mean_ms, r.p50_ms, r.p95_ms
    );
}

/// A labeled throughput row (tok/s style tables).
pub fn print_tps_row(label: &str, tps: f64, extra: &str) {
    println!("{label:<44} {tps:>10.2} tok/s  {extra}");
}

/// Time `MaskCache::get_or_compute` for a state that is already cached:
/// ns per hit over 1M iterations. Shared by the sampler and grammar
/// benches so they report the same quantity the same way.
pub fn measure_cache_hit_ns(
    cache: &mut webllm::grammar::MaskCache,
    matcher: &webllm::grammar::GrammarMatcher,
) -> f64 {
    let _warm = cache.get_or_compute(matcher);
    let iters = 1_000_000usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        let m = cache.get_or_compute(matcher);
        std::hint::black_box(&m);
    }
    t0.elapsed().as_secs_f64() * 1e9 / iters as f64
}

/// Deterministic synthetic tokenizer vocabulary (no artifacts needed):
/// all 256 single bytes first, then pseudo-random short strings over a
/// JSON-friendly alphabet. Grammar masks over this vocab behave like real
/// BPE vocabs for benching purposes (tight allowed sets inside strings,
/// broad ones at value starts).
pub fn synthetic_vocab(n: usize) -> Vec<Vec<u8>> {
    const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789 \"{}[]:,.-_etaoinshr";
    let mut v = Vec::with_capacity(n);
    for b in 0..=255u8 {
        if v.len() < n {
            v.push(vec![b]);
        }
    }
    let mut state = 0x9E37_79B9_7F4A_7C15u64;
    while v.len() < n {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        let len = 2 + (state % 6) as usize;
        let mut s = Vec::with_capacity(len);
        for i in 0..len {
            let x = (state >> (8 * (i % 8))) as usize;
            s.push(ALPHABET[x % ALPHABET.len()]);
        }
        v.push(s);
    }
    v
}
