//! Time-to-first-token vs prompt length (DESIGN.md A6): prefill cost
//! across the compiled chunk menu, native vs browser mode, llama-web.
//!
//! WebLLM compiles a fixed menu of prefill shapes (TVM static shapes);
//! the engine pads the prompt up to the smallest admissible chunk, so
//! TTFT is a staircase in prompt length — this bench draws the staircase.
//!
//! The reference-backend section always runs (artifact-free); the XLA
//! section repeats the staircase over compiled artifacts when present.

#[path = "common/mod.rs"]
mod common;

use webllm::models::{reference_model_config, Manifest};
use webllm::runtime::{thread_client, ModelBackend, ModelRuntime, ReferenceBackend};

/// Draw the prefill staircase for one backend's compiled chunk menu.
fn staircase(label: &str, backend: &mut dyn ModelBackend) {
    let mc = backend.config().clone();
    let mp = mc.max_pages_per_seq();
    let reps = common::iters(8, 2);

    common::print_header(&format!("prefill staircase ({label})"));
    let chunks = mc.prefill_chunks.clone();
    let mut per_chunk = Vec::new();
    for &chunk in &chunks {
        let seq_len = chunk; // fully-used chunk
        let ids = vec![9i32; chunk];
        let mut bt = vec![0i32; mp];
        let pages_needed = (seq_len + 1 + mc.page_size - 1) / mc.page_size;
        for (i, b) in bt.iter_mut().take(pages_needed).enumerate() {
            *b = 1 + i as i32;
        }
        backend.reset_cache().unwrap();
        let r = common::time_it(&format!("prefill chunk={chunk}"), 1, reps, || {
            backend.prefill(&ids, seq_len, &bt).unwrap();
        });
        per_chunk.push((chunk, r.mean_ms));
        common::print_result(&r);
    }

    println!("\nTTFT staircase (prompt length -> padded chunk -> cost):");
    let lens: Vec<usize> = [4usize, 12, 24, 48, 96, 120]
        .iter()
        .copied()
        .filter(|&l| l <= *chunks.last().unwrap())
        .collect();
    for len in lens {
        let chunk = chunks.iter().copied().find(|&c| c >= len).unwrap();
        let cost = per_chunk.iter().find(|(c, _)| *c == chunk).unwrap().1;
        println!(
            "  prompt {len:>4} tok -> chunk {chunk:>4} -> {cost:>8.1} ms ({:.0}% padding waste)",
            100.0 * (chunk - len) as f64 / chunk as f64
        );
    }

    println!("\nper-token prefill efficiency:");
    for (chunk, ms) in &per_chunk {
        println!("  chunk {chunk:>4}: {:>7.2} ms/token", ms / *chunk as f64);
    }
}

fn main() {
    // Reference backend: in-code registry, runs everywhere.
    let mc = reference_model_config("tiny-ref").expect("registry");
    let mut reference = ReferenceBackend::new(mc, 7, None, None);
    staircase("tiny-ref, reference", &mut reference);

    // XLA runtime: compiled artifacts, when present.
    let model = if common::quick() { "tiny-2m" } else { "llama-web-80m" };
    match Manifest::load(&webllm::artifacts_dir()) {
        Ok(manifest) => {
            let client = thread_client().unwrap();
            let mut rt = ModelRuntime::load(&client, &manifest, model, None).expect("runtime");
            staircase(&format!("{model}, XLA"), &mut rt);
        }
        Err(_) => eprintln!(
            "SKIP: no artifacts in {} (run `make artifacts`); XLA staircase skipped",
            webllm::artifacts_dir().display()
        ),
    }
}
