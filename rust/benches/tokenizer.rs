//! Tokenizer throughput (DESIGN.md A7): the CPU-side subsystem the paper
//! runs as WASM. Native encode/decode rates, the modeled WASM slowdown,
//! and the streaming detokenizer.
//!
//! The reference-vocabulary section always runs (artifact-free); when
//! compiled artifacts exist the same battery repeats over the real merge
//! table, which is the number DESIGN.md A7 quotes.

#[path = "common/mod.rs"]
mod common;

use webllm::browser::{BrowserConfig, BrowserEnv};
use webllm::models::Manifest;
use webllm::tokenizer::{StreamDecoder, Tokenizer};

const SAMPLE: &str = "The inference engine keeps a paged key value cache. Each sequence owns \
a list of pages, and the attention kernel walks the page table to gather keys and values for \
every head. A scheduler batches prefill and decode requests so the device stays busy while \
responses stream out token by token. {\"json\": [1, 2.5, true], \"path\": \"/v1/chat\"} ";

/// The full measurement battery over one tokenizer.
fn bench_tokenizer(label: &str, tok: &Tokenizer) {
    let text = SAMPLE.repeat(common::iters(64, 8));
    let bytes = text.len();
    let reps = common::iters(100, 10);

    common::print_header(&format!("{label}: byte-level BPE over {} KiB", bytes / 1024));
    let ids = tok.encode(&text);
    let re = common::time_it("encode (native)", 3, reps, || {
        std::hint::black_box(tok.encode(&text));
    });
    common::print_result(&re);
    println!(
        "{:<44} {:>10.2} MiB/s | {:.2} chars/token",
        "",
        bytes as f64 / (re.mean_ms / 1e3) / (1 << 20) as f64,
        text.len() as f64 / ids.len() as f64
    );

    let rd = common::time_it("decode (native)", 3, reps, || {
        std::hint::black_box(tok.decode(&ids));
    });
    common::print_result(&rd);

    // WASM slowdown model: same work charged with the browser env.
    let env = BrowserEnv::new(BrowserConfig::default());
    let rw = common::time_it("encode (browser/WASM model)", 3, reps, || {
        std::hint::black_box(env.cpu_stage(|| tok.encode(&text)));
    });
    common::print_result(&rw);
    println!(
        "modeled WASM factor: {:.2}x (configured {:.2}x)",
        rw.mean_ms / re.mean_ms,
        BrowserConfig::default().wasm_slowdown
    );

    // Streaming detokenizer (per-token path in the engine hot loop).
    let rs = common::time_it("streaming detokenize (per stream)", 3, reps, || {
        let mut d = StreamDecoder::new();
        let mut out = String::new();
        for &id in &ids {
            out.push_str(&d.push(tok.token_bytes(id)));
        }
        out.push_str(&d.finish());
        std::hint::black_box(out);
    });
    common::print_result(&rs);
    println!(
        "{:<44} {:>10.2} ns/token",
        "",
        rs.mean_ms * 1e6 / ids.len() as f64
    );
}

fn main() {
    // Reference vocabulary: in-code registry, runs everywhere.
    bench_tokenizer("reference vocab", &webllm::models::reference_tokenizer());

    // Artifact vocabulary: the real merge table, when compiled.
    match Manifest::load(&webllm::artifacts_dir()) {
        Ok(manifest) => {
            let tok = Tokenizer::from_file(&manifest.tokenizer_path).expect("tokenizer");
            bench_tokenizer("artifact vocab", &tok);
        }
        Err(_) => eprintln!(
            "SKIP: no artifacts in {} (run `make artifacts`); artifact-vocab section skipped",
            webllm::artifacts_dir().display()
        ),
    }
}
