//! Paged-KV metadata throughput (DESIGN.md A4; the WASM "sequence
//! management in the paged KV-cache" subsystem of §2.2): allocator churn,
//! admission/free cycles, block-table materialization, and prefix-cache
//! hit rates under a shared-prefix workload.

#[path = "common/mod.rs"]
mod common;

use webllm::kvcache::{BlockAllocator, KvCacheManager};

fn main() {
    let n = common::iters(200_000, 5_000);

    // -- raw allocator ------------------------------------------------------
    let mut alloc = BlockAllocator::new(4096, 16);
    let r = common::time_it("alloc/release pair", 1000, 5, || {
        for _ in 0..n {
            let p = alloc.alloc().unwrap();
            alloc.release(p, false);
        }
    });
    common::print_header("block allocator");
    println!(
        "{:<44} {:>12.1} Mops/s",
        "alloc+release",
        n as f64 / (r.mean_ms / 1e3) / 1e6
    );

    // -- sequence admission / decode growth / free --------------------------
    let seqs = common::iters(2000, 100);
    let mut m = KvCacheManager::new(8192, 16, 32, false);
    let r = common::time_it("admit(64 tok) + 64 appends + free", 5, 5, || {
        for i in 0..seqs {
            let id = i as u64 + 1;
            let toks: Vec<u32> = (0..64).map(|t| (i * 64 + t) as u32 % 1000).collect();
            m.admit(id, &toks).unwrap();
            for t in 0..64u32 {
                m.append_token(id, t).unwrap();
            }
            m.free(id);
        }
    });
    common::print_header("sequence lifecycle");
    common::print_result(&r);
    println!(
        "{:<44} {:>12.1} k seqs/s",
        "full lifecycle",
        seqs as f64 / (r.mean_ms / 1e3) / 1e3
    );

    // -- block-table materialization (per decode step, hot path) ------------
    let mut m = KvCacheManager::new(1024, 16, 16, false);
    for i in 0..8u64 {
        m.admit(i + 1, &vec![7u32; 100]).unwrap();
    }
    let steps = common::iters(100_000, 2_000);
    let r = common::time_it("block_table_row x8 (one decode step)", 100, 5, || {
        for _ in 0..steps {
            for i in 0..8u64 {
                std::hint::black_box(m.block_table_row(i + 1));
            }
        }
    });
    common::print_header("decode-step table build");
    println!(
        "{:<44} {:>12.2} us/step",
        "8-row block tables",
        r.mean_ms * 1e3 / steps as f64
    );

    // -- prefix cache under shared-prefix workload ---------------------------
    common::print_header("prefix cache (shared system prompt)");
    for enabled in [false, true] {
        let mut m = KvCacheManager::new(4096, 16, 32, enabled);
        let prefix: Vec<u32> = (0..64).collect(); // 4 full pages
        let rounds = common::iters(500, 50);
        for i in 0..rounds {
            let id = i as u64 + 1;
            let mut toks = prefix.clone();
            toks.extend((0..10).map(|t| 1000 + (i * 10 + t) as u32));
            m.admit(id, &toks).unwrap();
            m.free(id);
        }
        let (hits, misses) = m.prefix_stats();
        println!(
            "prefix_cache={:<5} lookups {:>6} | hits {:>6} | hit rate {:>5.1}% | cached tokens avoided/seq ~{}",
            enabled,
            hits + misses,
            hits,
            100.0 * hits as f64 / (hits + misses).max(1) as f64,
            if enabled { 64 } else { 0 }
        );
    }
}
