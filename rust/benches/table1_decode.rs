//! **Table 1** — the paper's headline: decoding throughput of WebLLM
//! (in-browser) vs MLC-LLM (native) on the same device, 4-bit models.
//!
//! Mapping (DESIGN.md §4-T1, §5):
//!   * "MLC-LLM (native)"  -> `MLCEngine` driven in-process, no worker, no
//!     overhead model — the Python/C++-free native engine shape.
//!   * "WebLLM (browser)"  -> `ServiceWorkerMLCEngine` over the worker
//!     JSON channel with the WebGPU-dispatch + WASM cost model enabled.
//!   * Llama-3.1-8B  -> llama-web-80m; Phi-3.5-mini -> phi-web-38m
//!     (architecture-preserving scaled stand-ins; ratio is the target,
//!     not absolute tok/s).
//!
//! Workload per cell: single stream (bs=1, like the paper's chat
//! setting), ~40-token prompt, N decoded tokens, greedy.
//!
//! Run: `cargo bench --bench table1_decode` (WEBLLM_BENCH_QUICK=1 for a
//! smoke run).

#[path = "common/mod.rs"]
mod common;

use webllm::api::ChatCompletionRequest;
use webllm::coordinator::{EngineConfig, MLCEngine, ServiceWorkerMLCEngine};

const PROMPT: &str = "The browser loads the model and streams tokens back to the page. \
Describe, in detail, how the engine schedules prefill and decode.";

fn request(model: &str, max_tokens: usize) -> ChatCompletionRequest {
    let mut req = ChatCompletionRequest::new(model).user(PROMPT);
    req.max_tokens = max_tokens;
    req.sampling.temperature = 0.0; // deterministic decode-bound workload
    req
}

struct Cell {
    tok_s: f64,
    ttft_s: f64,
}

fn native_cell(model: &str, max_tokens: usize) -> Cell {
    let mut engine = MLCEngine::new(&EngineConfig::native(&[model])).expect("native engine");
    // Warmup: one short completion (compile caches, page pools touched).
    engine.chat_completion(request(model, 4)).expect("warmup");
    let resp = engine.chat_completion(request(model, max_tokens)).expect("bench run");
    Cell { tok_s: resp.usage.decode_tokens_per_s, ttft_s: resp.usage.ttft_s }
}

fn browser_cell(model: &str, max_tokens: usize) -> Cell {
    let mut engine =
        ServiceWorkerMLCEngine::create(EngineConfig::browser(&[model])).expect("browser engine");
    engine.chat_completion(request(model, 4)).expect("warmup");
    let resp = engine.chat_completion(request(model, max_tokens)).expect("bench run");
    Cell { tok_s: resp.usage.decode_tokens_per_s, ttft_s: resp.usage.ttft_s }
}

fn main() {
    let max_tokens = common::iters(96, 12);
    let models: &[(&str, &str)] = &[
        ("llama-web-80m", "Llama-3.1-8B"),
        ("phi-web-38m", "Phi-3.5-mini (3.8B)"),
    ];

    println!("Table 1 reproduction — decoding throughput (tok/s), bs=1, {max_tokens} decoded tokens");
    println!(
        "{:<22} {:>16} {:>16} {:>15}   (paper: 41.1/57.7=71.2%, 71.1/89.3=79.6%)",
        "Model", "WebLLM (tok/s)", "MLC-LLM (tok/s)", "Perf. Retained"
    );

    let mut rows = Vec::new();
    for (model, paper_name) in models {
        let native = native_cell(model, max_tokens);
        let browser = browser_cell(model, max_tokens);
        let retained = 100.0 * browser.tok_s / native.tok_s;
        println!(
            "{:<22} {:>16.2} {:>16.2} {:>14.1}%",
            format!("{paper_name} -> {model}"),
            browser.tok_s,
            native.tok_s,
            retained
        );
        rows.push((paper_name.to_string(), browser, native, retained));
    }

    println!("\nsupplementary (TTFT, same runs):");
    for (name, browser, native, _) in &rows {
        println!(
            "  {:<22} browser ttft {:.3}s | native ttft {:.3}s",
            name, browser.ttft_s, native.ttft_s
        );
    }

    // Shape checks mirroring the paper's claims (soft: print, don't panic).
    if rows.len() == 2 {
        let bigger_retained = rows[0].3;
        let smaller_retained = rows[1].3;
        println!("\nshape check: larger model retains less ({bigger_retained:.1}%) than smaller ({smaller_retained:.1}%): {}",
            if bigger_retained < smaller_retained { "OK (matches paper ordering)" } else { "MISMATCH" });
        println!(
            "shape check: retention in 60-90% band: {}",
            if rows.iter().all(|r| r.3 > 55.0 && r.3 < 95.0) { "OK" } else { "OUT OF BAND" }
        );
    }
}
