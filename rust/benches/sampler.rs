//! Per-token sampling cost: the legacy full-sort pipeline vs the fused
//! bitset + partial-selection pipeline (ISSUE 1 acceptance bench).
//!
//! The baseline is the seed's decode-path token cost, kept verbatim:
//! per-token `Vec<bool>` mask clone (+ EOS bit writes), a logits-row copy,
//! `-inf` materialization, and a full descending sort of every finite
//! logit. The fused path is `LogitsProcessor::sample_masked`:
//! word-skipping bitmask candidate collection, `select_nth`-based
//! truncation, lazy descending walk, reusable scratch.
//!
//! Also measures the grammar mask-cache hit cost (an `Rc` clone) against
//! the cold mask computation and against the old per-hit `Vec<bool>`
//! clone, demonstrating the O(1)-hit contract.
//!
//! Writes results to ../BENCH_sampling.json (repo root).

#[path = "common/mod.rs"]
mod common;

use std::rc::Rc;
use std::time::Instant;
use webllm::grammar::{schema_to_grammar, GrammarMatcher, MaskCache, TokenBitmask, VocabTrie};
use webllm::json::parse;
use webllm::sampler::{LogitsProcessor, Pcg32, SamplingParams};

// ---------------------------------------------------------------------------
// baseline: the pre-bitset pipeline, verbatim
// ---------------------------------------------------------------------------

struct BaselineSampler {
    rng: Pcg32,
    scratch: Vec<(u32, f32)>,
}

impl BaselineSampler {
    fn new(seed: u64) -> Self {
        Self { rng: Pcg32::new(seed), scratch: Vec::new() }
    }

    /// One token, legacy style. `mask`/`eos` trigger the per-token mask
    /// clone + `-inf` materialization the old engine performed.
    fn sample(
        &mut self,
        logits: &mut [f32],
        mask: Option<&[bool]>,
        eos: &[u32],
        params: &SamplingParams,
    ) -> u32 {
        if let Some(m) = mask {
            let mut mk = m.to_vec(); // the old per-token O(vocab) copy
            for &e in eos {
                if (e as usize) < mk.len() {
                    mk[e as usize] = true;
                }
            }
            if !mk.iter().any(|&ok| ok) {
                return argmax(logits);
            }
            for (l, &ok) in logits.iter_mut().zip(&mk) {
                if !ok {
                    *l = f32::NEG_INFINITY;
                }
            }
        }
        if params.temperature == 0.0 {
            return argmax(logits);
        }
        self.sample_stochastic(logits, params)
    }

    /// The seed's `sample_stochastic`, unchanged: full descending sort of
    /// every finite logit, fresh probs Vec per call.
    fn sample_stochastic(&mut self, logits: &[f32], p: &SamplingParams) -> u32 {
        let inv_t = 1.0 / p.temperature;
        self.scratch.clear();
        for (i, &l) in logits.iter().enumerate() {
            if l.is_finite() {
                self.scratch.push((i as u32, l * inv_t));
            }
        }
        if self.scratch.is_empty() {
            return argmax(logits);
        }
        self.scratch
            .sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        let mut n = self.scratch.len();
        if p.top_k > 0 {
            n = n.min(p.top_k);
        }
        let m = self.scratch[0].1;
        let mut total = 0.0f32;
        let mut probs: Vec<f32> = Vec::with_capacity(n);
        for &(_, l) in &self.scratch[..n] {
            let e = (l - m).exp();
            probs.push(e);
            total += e;
        }
        for q in &mut probs {
            *q /= total;
        }
        if p.min_p > 0.0 {
            let floor = p.min_p * probs[0];
            let keep = probs.iter().take_while(|&&q| q >= floor).count().max(1);
            if keep < n {
                n = keep;
                let t: f32 = probs[..n].iter().sum();
                probs.truncate(n);
                for q in &mut probs {
                    *q /= t;
                }
            }
        }
        if p.top_p < 1.0 {
            let mut cum = 0.0f32;
            let mut keep = n;
            for (i, &q) in probs.iter().enumerate() {
                cum += q;
                if cum >= p.top_p {
                    keep = i + 1;
                    break;
                }
            }
            if keep < n {
                n = keep;
                let t: f32 = probs[..n].iter().sum();
                probs.truncate(n);
                for q in &mut probs {
                    *q /= t;
                }
            }
        }
        let r = self.rng.f32();
        let mut cum = 0.0f32;
        for (i, &q) in probs[..n].iter().enumerate() {
            cum += q;
            if r < cum {
                return self.scratch[i].0;
            }
        }
        self.scratch[n - 1].0
    }
}

fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        if l > best_v {
            best_v = l;
            best = i;
        }
    }
    best as u32
}

// ---------------------------------------------------------------------------

fn gen_logits(vocab: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..vocab).map(|_| rng.f32() * 16.0 - 8.0).collect()
}

/// A grammar-shaped mask allowing roughly `1/stride` of the vocab.
fn sparse_mask(vocab: usize, stride: usize) -> (Vec<bool>, TokenBitmask) {
    let bools: Vec<bool> = (0..vocab).map(|i| i % stride == 0).collect();
    let bits = TokenBitmask::from_bools(&bools);
    (bools, bits)
}

struct Case {
    name: &'static str,
    params: SamplingParams,
    mask_stride: Option<usize>,
}

fn cases() -> Vec<Case> {
    let topp = SamplingParams { temperature: 0.8, top_p: 0.95, ..Default::default() };
    let topk = SamplingParams { temperature: 1.0, top_k: 40, top_p: 0.9, ..Default::default() };
    vec![
        Case { name: "greedy unmasked", params: SamplingParams::greedy(), mask_stride: None },
        Case {
            name: "greedy mask(1/97)",
            params: SamplingParams::greedy(),
            mask_stride: Some(97),
        },
        Case { name: "top-p .95 t.8 unmasked", params: topp.clone(), mask_stride: None },
        Case { name: "top-p .95 t.8 mask(1/97)", params: topp, mask_stride: Some(97) },
        Case { name: "top-k 40 top-p .9 unmasked", params: topk, mask_stride: None },
    ]
}

fn main() {
    let vocabs: Vec<usize> =
        if common::quick() { vec![32_768] } else { vec![32_768, 131_072] };
    let mut rows = Vec::new();

    for &vocab in &vocabs {
        let logits = gen_logits(vocab, 0xBEEF);
        let iters = common::iters((4_000_000 / vocab).max(64), 32);
        common::print_header(&format!("per-token sampling, vocab {vocab} ({iters} tokens)"));

        for case in cases() {
            let masks = case.mask_stride.map(|s| sparse_mask(vocab, s));
            let eos: &[u32] = &[2];

            // Baseline: per-token row copy + mask clone + full sort.
            let mut base = BaselineSampler::new(7);
            let rb = common::time_it(&format!("baseline  {}", case.name), 8, iters, || {
                let mut row = logits.clone();
                let t = base.sample(
                    &mut row,
                    masks.as_ref().map(|(b, _)| b.as_slice()),
                    eos,
                    &case.params,
                );
                std::hint::black_box(t);
            });

            // Fused: in-place, bitmask, partial selection.
            let mut proc = LogitsProcessor::new(case.params.clone(), 7);
            let mut row = logits.clone();
            let rf = common::time_it(&format!("fused     {}", case.name), 8, iters, || {
                let t = proc.sample_masked(&mut row, masks.as_ref().map(|(_, m)| m), eos);
                std::hint::black_box(t);
            });

            common::print_result(&rb);
            common::print_result(&rf);
            let speedup = rb.mean_ms / rf.mean_ms.max(1e-9);
            println!("{:<44} {speedup:>29.2}x", format!("  -> speedup {}", case.name));
            rows.push(webllm::obj! {
                "case" => case.name,
                "vocab" => vocab as i64,
                "tokens" => iters as i64,
                "baseline_us_per_token" => rb.mean_ms * 1e3,
                "fused_us_per_token" => rf.mean_ms * 1e3,
                "speedup" => speedup,
            });
        }
    }

    // -- grammar mask-cache hit cost (the O(1) contract) --------------------
    let vocab = vocabs[0];
    let raw = common::synthetic_vocab(vocab);
    let trie = Rc::new(VocabTrie::build(vocab, |i| raw[i as usize].as_slice()));
    let schema = parse(
        r#"{"type":"object","properties":{"name":{"type":"string"},
            "count":{"type":"integer"}},"required":["name","count"]}"#,
    )
    .unwrap();
    let grammar = Rc::new(schema_to_grammar(&schema).unwrap());
    let mut matcher = GrammarMatcher::new(grammar.clone());
    assert!(matcher.advance_bytes(b"{\"name\":\"we"), "grammar walk");

    let cold_iters = common::iters(30, 4);
    let rc = common::time_it(&format!("cold mask compute (vocab {vocab})"), 2, cold_iters, || {
        let m = matcher.token_mask_trie(&trie);
        std::hint::black_box(&m);
    });

    let compiled = Rc::new(webllm::grammar::CompiledGrammar::compile(grammar, &trie, |i| {
        raw[i as usize].as_slice()
    }));
    let mut cache = MaskCache::new(compiled, 256);
    let hit_ns = common::measure_cache_hit_ns(&mut cache, &matcher);

    // The old per-hit cost for comparison: cloning an unpacked vocab mask.
    let bools = vec![true; vocab];
    let t0 = Instant::now();
    let clone_iters = 100_000usize;
    for _ in 0..clone_iters {
        let c = bools.clone();
        std::hint::black_box(&c);
    }
    let clone_ns = t0.elapsed().as_secs_f64() * 1e9 / clone_iters as f64;

    common::print_header("grammar mask cache");
    common::print_result(&rc);
    println!("cache hit (Rc clone):            {hit_ns:>10.1} ns");
    println!("legacy hit (Vec<bool> clone):    {clone_ns:>10.1} ns");
    println!(
        "hit is {:.0}x cheaper than the old vocab-sized copy and {:.0}x cheaper than recompute",
        clone_ns / hit_ns.max(1e-9),
        rc.mean_ms * 1e6 / hit_ns.max(1e-9)
    );
    let (hits, misses) = cache.stats();

    // -- JSON report --------------------------------------------------------
    let report = webllm::obj! {
        "bench" => "sampler",
        "generated_by" => "cargo bench --bench sampler",
        "quick_mode" => common::quick(),
        "per_token_sampling" => webllm::json::Value::Array(rows),
        "mask_cache" => webllm::obj! {
            "vocab" => vocab as i64,
            "cold_mask_compute_us" => rc.mean_ms * 1e3,
            "cache_hit_ns" => hit_ns,
            "legacy_vec_bool_clone_ns" => clone_ns,
            "hits" => hits as i64,
            "misses" => misses as i64,
        },
    };
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_sampling.json");
    match std::fs::write(&path, webllm::json::to_string_pretty(&report) + "\n") {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", path.display()),
    }
}
