//! Multi-token emission (DESIGN.md A8): grammar fast-forward + draft
//! speculation on a constrained-JSON workload, reference backend
//! (always runs — part of the CI perf smoke).
//!
//! Four configurations over the same greedy JSON-schema requests:
//! a plain one-token-per-step baseline, fast-forward only, self-draft
//! speculation + fast-forward (the headline: tokens per target decode
//! step must clear 1.5x), and a divergent drafter that exercises the
//! rejection/rollback path. Output text is identical across all four —
//! the engine only reshapes the schedule, never the stream.
//!
//! Writes ../BENCH_specdec.json (repo root).

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;
use webllm::api::{ChatCompletionRequest, ResponseFormat};
use webllm::coordinator::{EngineConfig, MLCEngine};
use webllm::json::parse;

const TARGET: &str = "tiny-ref";

/// Greedy JSON-schema request. Two forced property spans around two free
/// choice points (bool, digits); the '}' nudge closes the integer after
/// a few digits so derivations finish well inside max_tokens. A distinct
/// prompt per request keeps the prefix cache out of the measurement.
fn schema_request(i: usize) -> ChatCompletionRequest {
    let schema = r#"{
        "type": "object",
        "properties": {"status": {"type": "boolean"}, "count": {"type": "integer"}},
        "required": ["status", "count"]
    }"#;
    let mut r = ChatCompletionRequest::new(TARGET).user(format!("structured request {i:02}"));
    r.max_tokens = 100;
    r.sampling.temperature = 0.0;
    r.sampling.logit_bias.insert(8 + b'}' as u32, 5.0); // byte-token id of '}'
    r.response_format = ResponseFormat::JsonSchema(parse(schema).unwrap());
    r
}

struct Run {
    label: &'static str,
    completion: usize,
    decode_steps: i64,
    decode_tokens: i64,
    ff_tokens: i64,
    spec_steps: i64,
    draft_proposed: i64,
    draft_accepted: i64,
    accept_rate: f64,
    wall_s: f64,
    text: String,
}

impl Run {
    /// Decode-phase emissions per target decode call. Each request's
    /// first token comes from prefill, so it is excluded; the plain
    /// baseline lands at exactly 1.0 by construction.
    fn tokens_per_step(&self, n_requests: usize) -> f64 {
        (self.completion - n_requests) as f64 / self.decode_steps.max(1) as f64
    }

    /// Fraction of completion tokens emitted by fast-forward (zero model
    /// and sampler calls).
    fn ff_fraction(&self) -> f64 {
        self.ff_tokens as f64 / (self.completion as f64).max(1.0)
    }
}

fn run(label: &'static str, draft: Option<&str>, ff: bool, n_requests: usize) -> Run {
    let mut cfg = EngineConfig::reference(&[TARGET]);
    cfg.draft_model = draft.map(str::to_string);
    cfg.enable_fast_forward = ff;
    // The four headline configs keep fixed-k speculation so their rows
    // stay comparable across runs; the adaptive policy gets its own
    // section below.
    cfg.adaptive_spec_tokens = false;
    let mut engine = MLCEngine::new(&cfg).expect("reference engine");

    let mut completion = 0usize;
    let mut text = String::new();
    let t0 = Instant::now();
    for i in 0..n_requests {
        let resp = engine.chat_completion(schema_request(i)).expect("completion");
        completion += resp.usage.completion_tokens;
        if i == 0 {
            text = resp.text().to_string();
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let stats = engine.stats_json();
    let top = |k: &str| stats.get(k).unwrap().as_i64().unwrap();
    let spec = stats.get("speculative").unwrap();
    let sp = |k: &str| spec.get(k).unwrap().as_i64().unwrap();
    Run {
        label,
        completion,
        decode_steps: top("decode_steps"),
        decode_tokens: top("decode_tokens"),
        ff_tokens: sp("ff_tokens"),
        spec_steps: sp("spec_steps"),
        draft_proposed: sp("draft_proposed"),
        draft_accepted: sp("draft_accepted"),
        accept_rate: spec.get("draft_accept_rate").unwrap().as_f64().unwrap(),
        wall_s,
        text,
    }
}

fn report(r: &Run, n_requests: usize) -> webllm::json::Value {
    println!(
        "{:<36} {:>5.2} tok/step | {:>4} tok / {:>3} decode steps | ff {:>4.0}% | \
         accept {:>4.0}% | {:>7.1} ms",
        r.label,
        r.tokens_per_step(n_requests),
        r.completion,
        r.decode_steps,
        100.0 * r.ff_fraction(),
        100.0 * r.accept_rate,
        r.wall_s * 1e3,
    );
    webllm::obj! {
        "config" => r.label,
        "tokens_per_step" => r.tokens_per_step(n_requests),
        "completion_tokens" => r.completion as i64,
        "decode_steps" => r.decode_steps,
        "decode_tokens" => r.decode_tokens,
        "ff_tokens" => r.ff_tokens,
        "ff_fraction" => r.ff_fraction(),
        "spec_steps" => r.spec_steps,
        "draft_proposed" => r.draft_proposed,
        "draft_accepted" => r.draft_accepted,
        "draft_accept_rate" => r.accept_rate,
        "wall_ms" => r.wall_s * 1e3,
    }
}

/// Mixed-accept-rate trace for the adaptive-k section: even requests
/// are grammar-constrained (the divergent drafter tracks forced spans
/// well, so acceptance is high), odd ones are free-text sampling at
/// temperature 0.9 (verification re-samples, so most proposals lose the
/// draw and acceptance is low). A fixed k pays full draft cost on both
/// halves; the per-request EWMA should shrink k only where it loses.
fn mixed_request(i: usize) -> ChatCompletionRequest {
    if i % 2 == 0 {
        return schema_request(i);
    }
    let mut r = ChatCompletionRequest::new(TARGET).user(format!("free text {i:02}"));
    r.max_tokens = 24;
    r.sampling.temperature = 0.9;
    r.sampling.seed = Some(0xAD0_5EED + i as u64);
    webllm::testutil::ban_reference_eos(&mut r);
    r
}

struct MixedRun {
    texts: Vec<String>,
    completion: usize,
    decode_steps: i64,
    proposed: i64,
    accepted: i64,
    wall_s: f64,
}

impl MixedRun {
    /// Draft tokens proposed but rejected: pure speculative overhead.
    fn waste(&self) -> i64 {
        self.proposed - self.accepted
    }
}

fn mixed_run(adaptive: bool, n_requests: usize) -> MixedRun {
    let mut cfg = EngineConfig::reference(&[TARGET]);
    cfg.draft_model = Some("tiny-ref-b".to_string());
    cfg.enable_fast_forward = true;
    cfg.adaptive_spec_tokens = adaptive;
    let mut engine = MLCEngine::new(&cfg).expect("reference engine");

    let mut out = MixedRun {
        texts: Vec::with_capacity(n_requests),
        completion: 0,
        decode_steps: 0,
        proposed: 0,
        accepted: 0,
        wall_s: 0.0,
    };
    let t0 = Instant::now();
    for i in 0..n_requests {
        let resp = engine.chat_completion(mixed_request(i)).expect("completion");
        out.completion += resp.usage.completion_tokens;
        out.texts.push(resp.text().to_string());
    }
    out.wall_s = t0.elapsed().as_secs_f64();

    let stats = engine.stats_json();
    let spec = stats.get("speculative").unwrap();
    out.decode_steps = stats.get("decode_steps").unwrap().as_i64().unwrap();
    out.proposed = spec.get("draft_proposed").unwrap().as_i64().unwrap();
    out.accepted = spec.get("draft_accepted").unwrap().as_i64().unwrap();
    out
}

fn report_mixed(label: &str, r: &MixedRun, n_requests: usize) -> webllm::json::Value {
    let tps = (r.completion - n_requests) as f64 / r.decode_steps.max(1) as f64;
    println!(
        "{:<36} {:>5.2} tok/step | proposed {:>4} accepted {:>4} wasted {:>4} | {:>7.1} ms",
        label,
        tps,
        r.proposed,
        r.accepted,
        r.waste(),
        r.wall_s * 1e3,
    );
    webllm::obj! {
        "config" => label,
        "tokens_per_step" => tps,
        "completion_tokens" => r.completion as i64,
        "decode_steps" => r.decode_steps,
        "draft_proposed" => r.proposed,
        "draft_accepted" => r.accepted,
        "draft_wasted" => r.waste(),
        "wall_ms" => r.wall_s * 1e3,
    }
}

fn main() {
    let n = common::iters(12, 4);
    println!(
        "=== multi-token emission on constrained JSON \
         ({n} greedy schema requests, tiny-ref) ==="
    );
    // Warm up allocators/caches once so the first measured run isn't cold.
    run("warmup", None, false, 1);

    let baseline = run("baseline (1 token/step)", None, false, n);
    let ff_only = run("fast-forward only", None, true, n);
    let headline = run("self-draft + ff (tiny-ref)", Some("tiny-ref"), true, n);
    let divergent = run("divergent draft + ff (tiny-ref-b)", Some("tiny-ref-b"), true, n);

    let runs = [&baseline, &ff_only, &headline, &divergent];
    let configs: Vec<_> = runs.iter().map(|r| report(r, n)).collect();
    for r in &runs[1..] {
        assert_eq!(r.text, baseline.text, "{}: output diverged from baseline", r.label);
    }
    println!(
        "headline: {:.2} tokens per target decode step (ff {} tok, accept {:.0}%)",
        headline.tokens_per_step(n),
        headline.ff_tokens,
        100.0 * headline.accept_rate,
    );

    // Adaptive spec_tokens vs fixed k on a mixed-accept-rate trace: the
    // per-request acceptance EWMA must cut draft waste (proposed but
    // rejected tokens) without changing a single output byte.
    let n_mixed = common::iters(16, 6);
    println!(
        "\n=== adaptive spec_tokens vs fixed k={} \
         (divergent draft, mixed-accept trace, {n_mixed} requests) ===",
        webllm::coordinator::DEFAULT_SPEC_TOKENS
    );
    let fixed_k = mixed_run(false, n_mixed);
    let adaptive_k = mixed_run(true, n_mixed);
    let mixed_configs = vec![
        report_mixed("fixed k (divergent draft)", &fixed_k, n_mixed),
        report_mixed("adaptive k (accept-rate EWMA)", &adaptive_k, n_mixed),
    ];
    assert_eq!(adaptive_k.texts, fixed_k.texts, "adaptive k changed output bytes");
    assert!(
        adaptive_k.waste() < fixed_k.waste(),
        "adaptive k must beat fixed k on draft waste: {} vs {}",
        adaptive_k.waste(),
        fixed_k.waste()
    );
    println!(
        "adaptive policy: {} wasted draft tokens vs {} fixed ({:.0}% less)",
        adaptive_k.waste(),
        fixed_k.waste(),
        100.0 * (1.0 - adaptive_k.waste() as f64 / fixed_k.waste().max(1) as f64),
    );

    let report = webllm::obj! {
        "bench" => "specdec",
        "generated_by" => "cargo bench --bench specdec",
        "quick_mode" => common::quick(),
        "scenario" => webllm::obj! {
            "description" => "greedy JSON-schema requests (two forced property spans, two \
                              free choice points) served four ways: plain baseline, grammar \
                              fast-forward, self-draft speculation + ff, divergent-draft \
                              speculation + ff. All four emit byte-identical text; \
                              tokens_per_step counts decode-phase emissions per target \
                              decode call (baseline = 1.0 by construction)",
            "backend" => "reference (seeded-deterministic, native mode)",
            "n_requests" => n as i64,
            "target" => TARGET,
        },
        "configs" => webllm::json::Value::Array(configs),
        "tokens_per_step" => headline.tokens_per_step(n),
        "draft_accept_rate" => headline.accept_rate,
        "ff_tokens" => headline.ff_tokens,
        "ff_fraction" => headline.ff_fraction(),
        "adaptive_policy" => webllm::obj! {
            "description" => "divergent drafter over a mixed trace (grammar-constrained \
                              requests interleaved with temperature-0.9 free text): the \
                              per-request acceptance EWMA shrinks k where proposals lose \
                              the verification draw, identical output bytes either way",
            "n_requests" => n_mixed as i64,
            "configs" => webllm::json::Value::Array(mixed_configs),
            "draft_wasted_fixed" => fixed_k.waste(),
            "draft_wasted_adaptive" => adaptive_k.waste(),
        },
    };
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("BENCH_specdec.json");
    match std::fs::write(&path, webllm::json::to_string_pretty(&report) + "\n") {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", path.display()),
    }
}
