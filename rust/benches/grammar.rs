//! Structured-generation overhead (DESIGN.md A3; paper §2.1/§2.2 — the
//! grammar engine is one of the WASM-compiled CPU subsystems).
//!
//! Measures: (1) decode throughput with vs without a JSON-Schema
//! constraint on the real engine; (2) the raw mask-computation cost and
//! the adaptive mask-cache hit rate that makes constrained decoding
//! near-free after warmup (the XGrammar claim).

#[path = "common/mod.rs"]
mod common;

use std::rc::Rc;
use webllm::api::{ChatCompletionRequest, ResponseFormat};
use webllm::coordinator::{EngineConfig, MLCEngine};
use webllm::grammar::{schema_to_grammar, GrammarMatcher, MaskCache, VocabTrie};
use webllm::json::parse;
use webllm::tokenizer::Tokenizer;

const SCHEMA: &str = r#"{
    "type": "object",
    "properties": {
        "title": {"type": "string"},
        "tags": {"type": "array", "items": {"type": "string"}, "maxItems": 4},
        "score": {"type": "number"}
    },
    "required": ["title", "tags", "score"]
}"#;

fn main() {
    let max_tokens = common::iters(48, 8);
    let reps = common::iters(6, 2);

    let mut engine = MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).expect("engine");
    let base = |constrained: bool| {
        let mut r = ChatCompletionRequest::new("tiny-2m").user("Summarize as JSON.");
        r.max_tokens = max_tokens;
        r.sampling.seed = Some(17);
        if constrained {
            r.response_format = ResponseFormat::JsonSchema(parse(SCHEMA).unwrap());
        }
        r
    };
    engine.chat_completion(base(false)).unwrap(); // warmup

    let mut free_tps = 0.0;
    let rf = common::time_it("unconstrained decode", 1, reps, || {
        let resp = engine.chat_completion(base(false)).unwrap();
        free_tps += resp.usage.decode_tokens_per_s;
    });
    let mut cons_tps = 0.0;
    let rc = common::time_it("json-schema constrained", 1, reps, || {
        let resp = engine.chat_completion(base(true)).unwrap();
        cons_tps += resp.usage.decode_tokens_per_s;
    });

    common::print_header(&format!("engine decode, {max_tokens} tokens (tiny-2m)"));
    common::print_result(&rf);
    common::print_result(&rc);
    println!(
        "\nconstrained-decoding overhead: {:.1}% (decode tok/s: {:.1} free vs {:.1} constrained)",
        100.0 * (rc.mean_ms - rf.mean_ms) / rf.mean_ms,
        free_tps / reps as f64,
        cons_tps / reps as f64,
    );

    // -- raw mask computation + cache --------------------------------------
    let manifest = webllm::models::Manifest::load(&webllm::artifacts_dir()).expect("artifacts");
    let tok = Tokenizer::from_file(&manifest.tokenizer_path).expect("tokenizer");
    let trie = Rc::new(VocabTrie::build(tok.vocab_size(), |i| tok.token_bytes(i)));
    let grammar = Rc::new(schema_to_grammar(&parse(SCHEMA).unwrap()).unwrap());

    let m = GrammarMatcher::new(grammar.clone());
    let r = common::time_it(
        &format!("cold token mask (vocab {}, trie {} nodes)", tok.vocab_size(), trie.node_count()),
        2,
        common::iters(50, 5),
        || {
            let mask = m.token_mask_trie(&trie);
            std::hint::black_box(&mask);
        },
    );
    common::print_header("grammar mask micro-bench");
    common::print_result(&r);

    // Simulated decode walk with the cache (greedy-ish random choices).
    let mut cache = MaskCache::new(trie.clone(), 256);
    let mut matcher = GrammarMatcher::new(grammar);
    let mut rng: u64 = 0x1234_5678;
    let steps = common::iters(400, 40);
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let mask = cache.get_or_compute(&matcher);
        let allowed: Vec<u32> =
            (0..tok.vocab_size() as u32).filter(|&i| mask[i as usize]).collect();
        if allowed.is_empty() {
            break;
        }
        rng ^= rng << 13;
        rng ^= rng >> 7;
        rng ^= rng << 17;
        let t = allowed[(rng % allowed.len() as u64) as usize];
        if !matcher.accept_token(tok.token_bytes(t)) {
            break;
        }
    }
    let (hits, misses) = cache.stats();
    println!(
        "cached walk: {steps} steps in {:.1} ms | mask cache {hits} hits / {misses} misses ({:.0}% hit rate)",
        t0.elapsed().as_secs_f64() * 1e3,
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    );
}
