//! Structured-generation overhead (DESIGN.md A3; paper §2.1/§2.2 — the
//! grammar engine is one of the WASM-compiled CPU subsystems).
//!
//! Measures, artifact-free on a synthetic vocabulary:
//!   (1) the raw mask-computation cost (cold `token_mask_trie` walk with
//!       the arena DFS) at several vocab sizes;
//!   (2) the adaptive mask-cache hit cost — an `Rc<TokenBitmask>` clone,
//!       O(1) in vocab size — and the hit rate over a simulated decode;
//! and, when artifacts are built:
//!   (3) decode throughput with vs without a JSON-Schema constraint on
//!       the real engine.

#[path = "common/mod.rs"]
mod common;

use std::rc::Rc;
use webllm::api::{ChatCompletionRequest, ResponseFormat};
use webllm::coordinator::{EngineConfig, MLCEngine};
use webllm::grammar::{schema_to_grammar, GrammarMatcher, MaskCache, VocabTrie};
use webllm::json::parse;
use webllm::tokenizer::Tokenizer;

const SCHEMA: &str = r#"{
    "type": "object",
    "properties": {
        "title": {"type": "string"},
        "tags": {"type": "array", "items": {"type": "string"}, "maxItems": 4},
        "score": {"type": "number"}
    },
    "required": ["title", "tags", "score"]
}"#;

fn main() {
    mask_microbench();
    if webllm::artifacts_dir().join("manifest.json").exists() {
        engine_bench();
    } else {
        println!("\n(artifacts not built; skipping engine decode section)");
    }
}

/// Mask computation + cache on a synthetic vocabulary (no artifacts).
fn mask_microbench() {
    let grammar = Rc::new(schema_to_grammar(&parse(SCHEMA).unwrap()).unwrap());
    let vocab_sizes: &[usize] =
        if common::quick() { &[32_768] } else { &[32_768, 131_072] };

    common::print_header("grammar mask micro-bench (synthetic vocab)");
    for &vocab in vocab_sizes {
        let raw = common::synthetic_vocab(vocab);
        let trie = Rc::new(VocabTrie::build(vocab, |i| raw[i as usize].as_slice()));

        // Cold walk from two representative states: value start (broad
        // mask) and inside a string (tight mask).
        let start = GrammarMatcher::new(grammar.clone());
        let r = common::time_it(
            &format!("cold mask @root (vocab {vocab}, trie {} nodes)", trie.node_count()),
            2,
            common::iters(30, 4),
            || {
                let mask = start.token_mask_trie(&trie);
                std::hint::black_box(&mask);
            },
        );
        common::print_result(&r);

        let mut in_string = GrammarMatcher::new(grammar.clone());
        assert!(in_string.advance_bytes(b"{\"title\":\"we"));
        let allowed = in_string.token_mask_trie(&trie).count_allowed();
        let r = common::time_it(
            &format!("cold mask @in-string ({allowed} allowed)"),
            2,
            common::iters(30, 4),
            || {
                let mask = in_string.token_mask_trie(&trie);
                std::hint::black_box(&mask);
            },
        );
        common::print_result(&r);

        // Cache hit: must be O(1) — an Rc pointer clone, independent of
        // vocab size.
        let mut cache = MaskCache::new(trie.clone(), 256);
        let warm = cache.get_or_compute(&in_string);
        let again = cache.get_or_compute(&in_string);
        assert!(Rc::ptr_eq(&warm, &again), "hit must be a pointer clone");
        let ns = common::measure_cache_hit_ns(&mut cache, &in_string);
        println!("cache hit @vocab {vocab}: {ns:.1} ns (Rc clone; O(1) in vocab)");
    }

    // Simulated decode walk with the cache (greedy-ish random choices)
    // over the smaller synthetic vocab: steady-state hit rate.
    let vocab = vocab_sizes[0];
    let raw = common::synthetic_vocab(vocab);
    let trie = Rc::new(VocabTrie::build(vocab, |i| raw[i as usize].as_slice()));
    let mut cache = MaskCache::new(trie.clone(), 256);
    let mut matcher = GrammarMatcher::new(grammar);
    let mut rng: u64 = 0x1234_5678;
    let steps = common::iters(400, 40);
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let mask = cache.get_or_compute(&matcher);
        let allowed: Vec<u32> = mask.iter_allowed().map(|i| i as u32).collect();
        if allowed.is_empty() {
            break;
        }
        rng ^= rng << 13;
        rng ^= rng >> 7;
        rng ^= rng << 17;
        let t = allowed[(rng % allowed.len() as u64) as usize];
        if !matcher.accept_token(raw[t as usize].as_slice()) {
            break;
        }
    }
    let (hits, misses) = cache.stats();
    println!(
        "cached walk: {steps} steps in {:.1} ms | mask cache {hits} hits / {misses} misses ({:.0}% hit rate)",
        t0.elapsed().as_secs_f64() * 1e3,
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    );
}

/// Engine decode with vs without a schema constraint (needs artifacts).
fn engine_bench() {
    let max_tokens = common::iters(48, 8);
    let reps = common::iters(6, 2);

    let mut engine = MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).expect("engine");
    let base = |constrained: bool| {
        let mut r = ChatCompletionRequest::new("tiny-2m").user("Summarize as JSON.");
        r.max_tokens = max_tokens;
        r.sampling.seed = Some(17);
        if constrained {
            r.response_format = ResponseFormat::JsonSchema(parse(SCHEMA).unwrap());
        }
        r
    };
    engine.chat_completion(base(false)).unwrap(); // warmup

    let mut free_tps = 0.0;
    let rf = common::time_it("unconstrained decode", 1, reps, || {
        let resp = engine.chat_completion(base(false)).unwrap();
        free_tps += resp.usage.decode_tokens_per_s;
    });
    let mut cons_tps = 0.0;
    let rc = common::time_it("json-schema constrained", 1, reps, || {
        let resp = engine.chat_completion(base(true)).unwrap();
        cons_tps += resp.usage.decode_tokens_per_s;
    });

    common::print_header(&format!("engine decode, {max_tokens} tokens (tiny-2m)"));
    common::print_result(&rf);
    common::print_result(&rc);
    println!(
        "\nconstrained-decoding overhead: {:.1}% (decode tok/s: {:.1} free vs {:.1} constrained)",
        100.0 * (rc.mean_ms - rf.mean_ms) / rf.mean_ms,
        free_tps / reps as f64,
        cons_tps / reps as f64,
    );

    // Real-tokenizer mask timing for reference against the synthetic one.
    let manifest = webllm::models::Manifest::load(&webllm::artifacts_dir()).expect("artifacts");
    let tok = Tokenizer::from_file(&manifest.tokenizer_path).expect("tokenizer");
    let trie = Rc::new(VocabTrie::build(tok.vocab_size(), |i| tok.token_bytes(i)));
    let grammar = Rc::new(schema_to_grammar(&parse(SCHEMA).unwrap()).unwrap());
    let m = GrammarMatcher::new(grammar);
    let r = common::time_it(
        &format!("cold token mask (vocab {}, trie {} nodes)", tok.vocab_size(), trie.node_count()),
        2,
        common::iters(50, 5),
        || {
            let mask = m.token_mask_trie(&trie);
            std::hint::black_box(&mask);
        },
    );
    common::print_header("grammar mask micro-bench (artifact tokenizer)");
    common::print_result(&r);
}
