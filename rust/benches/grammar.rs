//! Structured-generation overhead (DESIGN.md A3; paper §2.1/§2.2 — the
//! grammar engine is one of the WASM-compiled CPU subsystems).
//!
//! Measures, artifact-free on a synthetic vocabulary:
//!   (1) the one-shot AOT compile cost (`CompiledGrammar::compile`, the
//!       XGrammar compile-time analog) and the vocabulary partition it
//!       finds (context-independent fraction must be nonzero);
//!   (2) compile-time amortization: the per-state saving of the residue
//!       walk over the whole-vocabulary walk, and how many distinct
//!       automaton states pay back the compile;
//!   (3) the LRU mask-cache hit cost — an `Rc<TokenBitmask>` clone,
//!       O(1) in vocab size — and the hit rate over a simulated decode;
//! and, when artifacts are built:
//!   (4) decode throughput with vs without a JSON-Schema constraint on
//!       the real engine.

#[path = "common/mod.rs"]
mod common;

use std::rc::Rc;
use webllm::api::{ChatCompletionRequest, ResponseFormat};
use webllm::coordinator::{EngineConfig, MLCEngine};
use webllm::grammar::{
    parse_ebnf, schema_to_grammar, CompiledGrammar, Grammar, GrammarMatcher, MaskCache, VocabTrie,
};
use webllm::json::parse;
use webllm::tokenizer::Tokenizer;

const SCHEMA: &str = r#"{
    "type": "object",
    "properties": {
        "title": {"type": "string"},
        "tags": {"type": "array", "items": {"type": "string"}, "maxItems": 4},
        "score": {"type": "number"}
    },
    "required": ["title", "tags", "score"]
}"#;

const EBNF: &str = r#"root ::= ("ab" | "cd")+ [0-9] [0-9]?"#;

/// Realistic tool-call schemas exercising the extended keyword families
/// (pattern, format, bounded numerics, length bounds, typed maps, tuples).
const TOOL_SCHEMAS: &[(&str, &str)] = &[
    (
        "get_weather",
        r#"{
            "type": "object",
            "properties": {
                "location": {"type": "string", "pattern": "^[A-Za-z ]{1,32}$"},
                "units": {"enum": ["celsius", "fahrenheit"]},
                "days": {"type": "integer", "minimum": 1, "maximum": 14}
            },
            "required": ["location", "units"]
        }"#,
    ),
    (
        "create_event",
        r#"{
            "type": "object",
            "properties": {
                "title": {"type": "string", "maxLength": 64},
                "start": {"type": "string", "format": "date-time"},
                "attendees": {
                    "type": "array",
                    "items": {"type": "string", "format": "email"},
                    "maxItems": 8
                },
                "reminder_minutes": {"oneOf": [{"type": "integer"}, {"type": "null"}]}
            },
            "required": ["title", "start"]
        }"#,
    ),
    (
        "search_docs",
        r#"{
            "type": "object",
            "properties": {
                "query": {"type": "string", "minLength": 1, "maxLength": 128},
                "filters": {
                    "type": "object",
                    "additionalProperties": {"type": ["string", "null"]}
                },
                "range": {
                    "type": "array",
                    "prefixItems": [
                        {"type": "integer", "minimum": 0},
                        {"type": "integer", "minimum": 0}
                    ],
                    "items": false,
                    "minItems": 2
                },
                "top_k": {"type": "integer", "exclusiveMinimum": 0, "maximum": 100}
            },
            "required": ["query"]
        }"#,
    ),
];

fn main() {
    compile_bench();
    tool_call_bench();
    mask_microbench();
    if webllm::artifacts_dir().join("manifest.json").exists() {
        engine_bench();
    } else {
        println!("\n(artifacts not built; skipping engine decode section)");
    }
}

/// One-shot AOT compile cost + amortization against per-state savings.
fn compile_bench() {
    let vocab = if common::quick() { 32_768 } else { 131_072 };
    let raw = common::synthetic_vocab(vocab);
    let trie = Rc::new(VocabTrie::build(vocab, |i| raw[i as usize].as_slice()));

    let grammars: Vec<(&str, Rc<Grammar>)> = vec![
        ("json-schema", Rc::new(schema_to_grammar(&parse(SCHEMA).unwrap()).unwrap())),
        ("ebnf", Rc::new(parse_ebnf(EBNF).unwrap())),
    ];

    common::print_header(&format!(
        "grammar AOT compile, vocab {vocab} (XGrammar compile-time analog)"
    ));
    for (name, grammar) in grammars {
        let reps = common::iters(3, 1);
        let mut compiled: Option<CompiledGrammar> = None;
        let r = common::time_it(&format!("compile {name}"), 1, reps, || {
            compiled = Some(CompiledGrammar::compile(grammar.clone(), &trie, |i| {
                raw[i as usize].as_slice()
            }));
        });
        common::print_result(&r);
        let c = compiled.expect("at least one iteration ran");
        let ci = c.context_independent_fraction();
        println!(
            "  {name}: base_accept {} | base_reject {} | residue {} | \
             context-independent {:.1}% | {} ({} states)",
            c.base_accept().count_allowed(),
            c.base_reject().count_allowed(),
            c.residue().len(),
            100.0 * ci,
            if c.is_exact() { "exact" } else { "NFA approximation" },
            c.states_explored(),
        );
        // Acceptance gate: the AOT pass must classify part of the vocab.
        assert!(ci > 0.0, "{name}: context-independent fraction must be nonzero");

        // Per-state amortization: cold whole-vocab walk vs residue walk
        // at two representative states (start + mid-derivation).
        let states: Vec<GrammarMatcher> = {
            let start = GrammarMatcher::new(grammar.clone());
            let mut mid = GrammarMatcher::new(grammar.clone());
            let probe: &[u8] = if name == "ebnf" { b"ab" } else { b"{\"title\":\"we" };
            assert!(mid.advance_bytes(probe), "probe prefix rejected");
            vec![start, mid]
        };
        let iters = common::iters(20, 4);
        for (label, state) in ["@start", "@mid"].iter().zip(&states) {
            let rf = common::time_it(&format!("  {name} full walk {label}"), 1, iters, || {
                let m = state.token_mask_trie(&trie);
                std::hint::black_box(&m);
            });
            let rr = common::time_it(&format!("  {name} residue walk {label}"), 1, iters, || {
                let m = c.mask_for(state);
                std::hint::black_box(&m);
            });
            common::print_result(&rf);
            common::print_result(&rr);
            let saving_ms = rf.mean_ms - rr.mean_ms;
            if saving_ms > 0.0 {
                println!(
                    "  -> saves {saving_ms:.3} ms/state; compile ({:.1} ms) amortized after \
                     ~{:.0} distinct states",
                    r.mean_ms,
                    (r.mean_ms / saving_ms).ceil(),
                );
            } else {
                println!("  -> no saving at this state (residue ~ whole vocab)");
            }
        }
    }
}

/// Schema-compile + AOT + mask latency over the three tool-call schemas
/// — the request-admission cost a serving stack pays per distinct
/// `response_format` (amortized across requests by the engine's grammar
/// cache). Feeds the "grammar" section of BENCH_sampling.json.
fn tool_call_bench() {
    let vocab = if common::quick() { 32_768 } else { 131_072 };
    let raw = common::synthetic_vocab(vocab);
    let trie = Rc::new(VocabTrie::build(vocab, |i| raw[i as usize].as_slice()));

    common::print_header(&format!("tool-call schemas: compile + mask latency, vocab {vocab}"));
    for (name, text) in TOOL_SCHEMAS {
        let schema = parse(text).unwrap();
        let mut built: Option<Grammar> = None;
        let r = common::time_it(
            &format!("schema->grammar {name}"),
            1,
            common::iters(50, 5),
            || {
                built = Some(schema_to_grammar(&schema).unwrap());
            },
        );
        common::print_result(&r);
        let grammar = Rc::new(built.expect("at least one iteration ran"));

        let mut compiled: Option<CompiledGrammar> = None;
        let r = common::time_it(&format!("AOT compile {name}"), 1, common::iters(3, 1), || {
            compiled = Some(CompiledGrammar::compile(grammar.clone(), &trie, |i| {
                raw[i as usize].as_slice()
            }));
        });
        common::print_result(&r);
        let c = compiled.expect("at least one iteration ran");
        let ci = c.context_independent_fraction();
        println!(
            "  {name}: {} rules | context-independent {:.1}% | {}",
            grammar.rules.len(),
            100.0 * ci,
            if c.is_exact() { "exact" } else { "NFA approximation" },
        );
        // Acceptance gate: every tool-call schema must yield a nonzero
        // base partition, or the AOT pass is doing nothing for the
        // schemas it exists for.
        assert!(ci > 0.0, "{name}: context-independent fraction must be nonzero");

        let start = GrammarMatcher::new(grammar.clone());
        let mut mid = GrammarMatcher::new(grammar.clone());
        let probe: &[u8] = match *name {
            "get_weather" => b"{\"location\":\"Pa",
            "create_event" => b"{\"title\":\"sync",
            _ => b"{\"query\":\"web",
        };
        assert!(mid.advance_bytes(probe), "{name}: probe prefix rejected");
        for (label, state) in [("@start", &start), ("@mid", &mid)] {
            let r = common::time_it(
                &format!("  residue mask {name} {label}"),
                1,
                common::iters(20, 4),
                || {
                    let m = c.mask_for(state);
                    std::hint::black_box(&m);
                },
            );
            common::print_result(&r);
        }
    }
}

/// Mask computation + LRU cache on a synthetic vocabulary (no artifacts).
fn mask_microbench() {
    let grammar = Rc::new(schema_to_grammar(&parse(SCHEMA).unwrap()).unwrap());
    let vocab_sizes: &[usize] =
        if common::quick() { &[32_768] } else { &[32_768, 131_072] };

    common::print_header("grammar mask micro-bench (synthetic vocab)");
    for &vocab in vocab_sizes {
        let raw = common::synthetic_vocab(vocab);
        let trie = Rc::new(VocabTrie::build(vocab, |i| raw[i as usize].as_slice()));

        // Cold walk from two representative states: value start (broad
        // mask) and inside a string (tight mask).
        let start = GrammarMatcher::new(grammar.clone());
        let r = common::time_it(
            &format!("cold mask @root (vocab {vocab}, trie {} nodes)", trie.node_count()),
            2,
            common::iters(30, 4),
            || {
                let mask = start.token_mask_trie(&trie);
                std::hint::black_box(&mask);
            },
        );
        common::print_result(&r);

        let mut in_string = GrammarMatcher::new(grammar.clone());
        assert!(in_string.advance_bytes(b"{\"title\":\"we"));
        let allowed = in_string.token_mask_trie(&trie).count_allowed();
        let r = common::time_it(
            &format!("cold mask @in-string ({allowed} allowed)"),
            2,
            common::iters(30, 4),
            || {
                let mask = in_string.token_mask_trie(&trie);
                std::hint::black_box(&mask);
            },
        );
        common::print_result(&r);

        // Cache hit: must be O(1) — an Rc pointer clone, independent of
        // vocab size.
        let compiled = Rc::new(CompiledGrammar::compile(grammar.clone(), &trie, |i| {
            raw[i as usize].as_slice()
        }));
        let mut cache = MaskCache::new(compiled, 256);
        let warm = cache.get_or_compute(&in_string);
        let again = cache.get_or_compute(&in_string);
        assert!(Rc::ptr_eq(&warm, &again), "hit must be a pointer clone");
        let ns = common::measure_cache_hit_ns(&mut cache, &in_string);
        println!("cache hit @vocab {vocab}: {ns:.1} ns (Rc clone; O(1) in vocab)");
    }

    // Simulated decode walk with the cache (greedy-ish random choices)
    // over the smaller synthetic vocab: steady-state hit rate.
    let vocab = vocab_sizes[0];
    let raw = common::synthetic_vocab(vocab);
    let trie = Rc::new(VocabTrie::build(vocab, |i| raw[i as usize].as_slice()));
    let compiled = Rc::new(CompiledGrammar::compile(grammar.clone(), &trie, |i| {
        raw[i as usize].as_slice()
    }));
    let mut cache = MaskCache::new(compiled, 256);
    let mut matcher = GrammarMatcher::new(grammar);
    let mut rng: u64 = 0x1234_5678;
    let steps = common::iters(400, 40);
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let mask = cache.get_or_compute(&matcher);
        let allowed: Vec<u32> = mask.iter_allowed().map(|i| i as u32).collect();
        if allowed.is_empty() {
            break;
        }
        rng ^= rng << 13;
        rng ^= rng >> 7;
        rng ^= rng << 17;
        let t = allowed[(rng % allowed.len() as u64) as usize];
        if !matcher.accept_token(raw[t as usize].as_slice()) {
            break;
        }
    }
    let c = cache.counters();
    println!(
        "cached walk: {steps} steps in {:.1} ms | mask cache {} hits / {} misses / {} evictions \
         ({:.0}% hit rate, {} resident)",
        t0.elapsed().as_secs_f64() * 1e3,
        c.hits,
        c.misses,
        c.evictions,
        100.0 * c.hits as f64 / (c.hits + c.misses).max(1) as f64,
        c.entries,
    );
}

/// Engine decode with vs without a schema constraint (needs artifacts).
fn engine_bench() {
    let max_tokens = common::iters(48, 8);
    let reps = common::iters(6, 2);

    let mut engine = MLCEngine::new(&EngineConfig::native(&["tiny-2m"])).expect("engine");
    let base = |constrained: bool| {
        let mut r = ChatCompletionRequest::new("tiny-2m").user("Summarize as JSON.");
        r.max_tokens = max_tokens;
        r.sampling.seed = Some(17);
        if constrained {
            r.response_format = ResponseFormat::JsonSchema(parse(SCHEMA).unwrap());
        }
        r
    };
    engine.chat_completion(base(false)).unwrap(); // warmup

    let mut free_tps = 0.0;
    let rf = common::time_it("unconstrained decode", 1, reps, || {
        let resp = engine.chat_completion(base(false)).unwrap();
        free_tps += resp.usage.decode_tokens_per_s;
    });
    let mut cons_tps = 0.0;
    let rc = common::time_it("json-schema constrained", 1, reps, || {
        let resp = engine.chat_completion(base(true)).unwrap();
        cons_tps += resp.usage.decode_tokens_per_s;
    });

    common::print_header(&format!("engine decode, {max_tokens} tokens (tiny-2m)"));
    common::print_result(&rf);
    common::print_result(&rc);
    println!(
        "\nconstrained-decoding overhead: {:.1}% (decode tok/s: {:.1} free vs {:.1} constrained)",
        100.0 * (rc.mean_ms - rf.mean_ms) / rf.mean_ms,
        free_tps / reps as f64,
        cons_tps / reps as f64,
    );
    // The engine's AOT + cache counters for the constrained run.
    if let Some(g) = engine.stats_json().get("grammar") {
        println!("engine grammar stats: {}", webllm::json::to_string(g));
    }

    // Real-tokenizer mask timing for reference against the synthetic one.
    let manifest = webllm::models::Manifest::load(&webllm::artifacts_dir()).expect("artifacts");
    let tok = Tokenizer::from_file(&manifest.tokenizer_path).expect("tokenizer");
    let trie = Rc::new(VocabTrie::build(tok.vocab_size(), |i| tok.token_bytes(i)));
    let grammar = Rc::new(schema_to_grammar(&parse(SCHEMA).unwrap()).unwrap());
    let m = GrammarMatcher::new(grammar);
    let r = common::time_it(
        &format!("cold token mask (vocab {}, trie {} nodes)", tok.vocab_size(), trie.node_count()),
        2,
        common::iters(50, 5),
        || {
            let mask = m.token_mask_trie(&trie);
            std::hint::black_box(&mask);
        },
    );
    common::print_header("grammar mask micro-bench (artifact tokenizer)");
    common::print_result(&r);
}
