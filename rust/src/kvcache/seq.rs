//! Sequence state + the manager tying allocator and prefix cache together.

use super::{AllocError, BlockAllocator, PrefixCache};
use super::prefix::{page_key, PageKey};
use std::collections::HashMap;

pub type SeqId = u64;

/// One live sequence's KV residency.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub id: SeqId,
    /// All tokens in context (prompt + generated).
    pub tokens: Vec<u32>,
    /// Pages backing positions [0, tokens.len()), in order.
    pub block_table: Vec<u32>,
    /// How many leading tokens were served from the prefix cache.
    pub cached_tokens: usize,
    /// Positions `[0, written)` are resident in the backend page pool
    /// (reused from the prefix cache, prefilled, or written by a decode
    /// step). Trailing tokens past this point have been *sampled* but
    /// not yet written back. Maintained via
    /// [`KvCacheManager::note_written`].
    written: usize,
    /// Keys of the full pages backing this sequence (parallel prefix of
    /// block_table), used to register pages on free.
    page_keys: Vec<PageKey>,
}

impl Sequence {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Pool-resident length: positions `[0, written)` hold real KV.
    pub fn written(&self) -> usize {
        self.written
    }

    /// First prompt position whose logits must actually be computed: the
    /// prefix-cache boundary (`cached_tokens` leading tokens are already
    /// resident in reused pages), clamped so the *final* prompt token is
    /// always computed — its logits seed the first sampled token, so
    /// even a fully-cached prompt pays for exactly one position.
    pub fn prefill_start(&self) -> usize {
        self.cached_tokens.min(self.len().saturating_sub(1))
    }
}

/// Metadata manager for one model's page pool.
pub struct KvCacheManager {
    alloc: BlockAllocator,
    prefix: PrefixCache,
    seqs: HashMap<SeqId, Sequence>,
    max_pages_per_seq: usize,
    enable_prefix_cache: bool,
}

impl KvCacheManager {
    pub fn new(
        num_pages: usize,
        page_size: usize,
        max_pages_per_seq: usize,
        enable_prefix_cache: bool,
    ) -> Self {
        Self {
            alloc: BlockAllocator::new(num_pages, page_size),
            prefix: PrefixCache::new(),
            seqs: HashMap::new(),
            max_pages_per_seq,
            enable_prefix_cache,
        }
    }

    pub fn page_size(&self) -> usize {
        self.alloc.page_size()
    }

    pub fn max_pages_per_seq(&self) -> usize {
        self.max_pages_per_seq
    }

    pub fn available_pages(&self) -> usize {
        self.alloc.available()
    }

    pub fn prefix_stats(&self) -> (u64, u64) {
        self.prefix.stats()
    }

    pub fn num_sequences(&self) -> usize {
        self.seqs.len()
    }

    pub fn get(&self, id: SeqId) -> Option<&Sequence> {
        self.seqs.get(&id)
    }

    /// Pages needed to admit a prompt of `n` tokens plus one decode slot,
    /// ignoring possible prefix hits (conservative admission bound).
    pub fn pages_needed(&self, n_tokens: usize) -> usize {
        let ps = self.alloc.page_size();
        (n_tokens + 1 + ps - 1) / ps
    }

    /// Whether a prompt of `n` tokens fits right now.
    pub fn can_admit(&self, n_tokens: usize) -> bool {
        let need = self.pages_needed(n_tokens);
        need <= self.max_pages_per_seq && need <= self.alloc.available()
    }

    /// Allocate residency for a new sequence over `tokens` (the prompt).
    /// Serves full-page prefixes from the prefix cache where possible.
    /// Returns the sequence; `cached_tokens` says how many leading tokens
    /// need no prefill compute — the scheduler starts its first
    /// positioned chunk at [`Sequence::prefill_start`], so reused pages
    /// are never recomputed (their contents are read straight through
    /// the block table by the backend's chunk attention).
    pub fn admit(&mut self, id: SeqId, tokens: &[u32]) -> Result<&Sequence, AllocError> {
        assert!(!self.seqs.contains_key(&id), "sequence {id} already admitted");
        let ps = self.alloc.page_size();
        let n_pages = self.pages_needed(tokens.len());
        if n_pages > self.max_pages_per_seq {
            return Err(AllocError::SeqLimit);
        }

        let mut block_table = Vec::with_capacity(n_pages);
        let mut page_keys: Vec<PageKey> = Vec::new();
        let mut cached_tokens = 0usize;

        let full_pages = tokens.len() / ps;
        let mut parent: Option<PageKey> = None;
        let mut reusing = self.enable_prefix_cache;

        // Pass 1: reuse cached full pages while the chain matches.
        for p in 0..full_pages {
            if !reusing {
                break;
            }
            let key = page_key(parent, &tokens[p * ps..(p + 1) * ps]);
            match self.prefix.lookup(key) {
                Some(page) => {
                    self.alloc.retain(page);
                    block_table.push(page);
                    page_keys.push(key);
                    parent = Some(key);
                    cached_tokens += ps;
                }
                None => {
                    reusing = false;
                }
            }
        }

        // Pass 2: fresh pages for the remainder (compute keys as we go so
        // the pages can be registered for future reuse on free).
        let rollback = |alloc: &mut BlockAllocator,
                            prefix: &mut PrefixCache,
                            table: &[u32],
                            keys: &[PageKey]| {
            for (i, &page) in table.iter().enumerate() {
                let keep = i < keys.len() && prefix.contains_page(page);
                alloc.release(page, keep);
            }
        };

        while block_table.len() < n_pages {
            match self.alloc.alloc() {
                Ok(page) => {
                    let idx = block_table.len();
                    if idx < full_pages && self.enable_prefix_cache {
                        let key = page_key(parent, &tokens[idx * ps..(idx + 1) * ps]);
                        page_keys.push(key);
                        parent = Some(key);
                    }
                    block_table.push(page);
                }
                Err(e) => {
                    rollback(&mut self.alloc, &mut self.prefix, &block_table, &page_keys);
                    self.sync_evictions();
                    return Err(e);
                }
            }
        }
        self.sync_evictions();

        let seq = Sequence {
            id,
            tokens: tokens.to_vec(),
            block_table,
            cached_tokens,
            // Reused pages already hold their tokens; everything else is
            // resident only once the engine reports prefill/decode
            // progress through `note_written`.
            written: cached_tokens,
            page_keys,
        };
        Ok(self.seqs.entry(id).or_insert(seq))
    }

    /// Record that the backend has materialized positions `[0, upto)` of
    /// sequence `id` in the page pool (a prefill chunk landed, or a
    /// decode step wrote its token). Monotonic; positions never become
    /// unwritten. Only fully-written pages are registered in the prefix
    /// cache on [`Self::free`] — chunked prefill *reads* reused pages
    /// instead of rewriting them, so a page with an unwritten slot (e.g.
    /// from a request aborted mid-prefill) must never be offered for
    /// reuse.
    pub fn note_written(&mut self, id: SeqId, upto: usize) {
        if let Some(seq) = self.seqs.get_mut(&id) {
            debug_assert!(upto <= seq.tokens.len(), "written past sequence end");
            if upto > seq.written {
                seq.written = upto;
            }
        }
    }

    /// Record a generated token, growing the block table when the new
    /// position crosses into an unallocated page.
    pub fn append_token(&mut self, id: SeqId, token: u32) -> Result<(), AllocError> {
        let ps = self.alloc.page_size();
        let seq = self.seqs.get_mut(&id).expect("unknown sequence");
        let pos = seq.tokens.len();
        let page_idx = pos / ps;
        if page_idx >= self.max_pages_per_seq {
            return Err(AllocError::SeqLimit);
        }
        if page_idx >= seq.block_table.len() {
            let page = self.alloc.alloc()?;
            seq.block_table.push(page);
        }
        seq.tokens.push(token);
        self.sync_evictions();
        Ok(())
    }

    /// Grow `id`'s block table until it covers positions `[0, upto)`,
    /// without appending tokens — speculative verification writes a
    /// draft run's KV *before* knowing which tokens will be accepted, so
    /// the pages must exist up front. Already-covering tables are a
    /// no-op. On `OutOfPages` the pages allocated so far are kept (they
    /// are released by `free`/`truncate` like any other page).
    pub fn reserve(&mut self, id: SeqId, upto: usize) -> Result<(), AllocError> {
        let ps = self.alloc.page_size();
        let need = (upto + ps - 1) / ps;
        if need > self.max_pages_per_seq {
            return Err(AllocError::SeqLimit);
        }
        let mut result = Ok(());
        let seq = self.seqs.get_mut(&id).expect("unknown sequence");
        while seq.block_table.len() < need {
            match self.alloc.alloc() {
                Ok(page) => seq.block_table.push(page),
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
        self.sync_evictions();
        result
    }

    /// Drop `id`'s tokens past `new_len`, releasing pages wholly beyond
    /// the shortened sequence. Used to roll a speculative mirror back to
    /// the accepted prefix after a draft rejection. `written` and
    /// `cached_tokens` clamp down with the tokens: rejected positions
    /// become unwritten again, so they can neither be attended over nor
    /// registered for prefix reuse. No-op if the sequence is unknown or
    /// already short enough.
    pub fn truncate(&mut self, id: SeqId, new_len: usize) {
        let ps = self.alloc.page_size();
        let Some(seq) = self.seqs.get_mut(&id) else { return };
        if new_len >= seq.tokens.len() {
            return;
        }
        seq.tokens.truncate(new_len);
        let keep_pages = (new_len + ps - 1) / ps;
        while seq.block_table.len() > keep_pages {
            let page = seq.block_table.pop().unwrap();
            // A popped page can still be alive as another sequence's
            // prefix hit; `release` only parks/frees at refcount zero.
            let keep = self.prefix.contains_page(page);
            self.alloc.release(page, keep);
        }
        // Keys address *full* pages of the old token vector; only pages
        // still fully backed by surviving tokens keep their keys.
        seq.page_keys.truncate(new_len / ps);
        if seq.written > new_len {
            seq.written = new_len;
        }
        if seq.cached_tokens > new_len {
            seq.cached_tokens = new_len;
        }
        self.sync_evictions();
    }

    /// Free a sequence. Fully *written* pages (with computed keys) are
    /// registered in the prefix cache and parked evictable; the rest
    /// return to the free list. The `written` bound keeps pages with
    /// unwritten slots — a prompt aborted mid-prefill, or the final
    /// sampled-but-never-decoded token — out of the reuse pool.
    pub fn free(&mut self, id: SeqId) {
        let Some(seq) = self.seqs.remove(&id) else { return };
        let ps = self.alloc.page_size();
        let full_pages = seq.tokens.len().min(seq.written) / ps;
        for (i, &page) in seq.block_table.iter().enumerate() {
            let mut keep = false;
            if self.enable_prefix_cache && i < full_pages {
                // Key may be missing for pages past the originally-hashed
                // prompt prefix (tokens generated later); compute lazily.
                let key = if i < seq.page_keys.len() {
                    seq.page_keys[i]
                } else {
                    let parent = if i == 0 {
                        None
                    } else if i - 1 < seq.page_keys.len() {
                        Some(seq.page_keys[i - 1])
                    } else {
                        None
                    };
                    match parent {
                        None if i > 0 => 0, // broken chain: don't cache
                        p => page_key(p, &seq.tokens[i * ps..(i + 1) * ps]),
                    }
                };
                if key != 0 && self.alloc.refcount(page) == 1 {
                    self.prefix.insert(key, page);
                    keep = self.prefix.contains_page(page);
                }
            }
            // Shared pages stay alive through other sequences' refs.
            let keep = keep || self.prefix.contains_page(page);
            self.alloc.release(page, keep);
        }
        self.sync_evictions();
    }

    /// Discard ALL pool state — allocator, prefix cache, and every live
    /// sequence — after device loss. The physical pages backing them are
    /// gone with the device, so the usual [`Self::free`] path (which
    /// parks fully-written pages for prefix reuse) would serve garbage
    /// KV to future admissions; nothing may survive. Prefix hit/miss
    /// counters reset with the cache.
    pub fn invalidate_all(&mut self) {
        self.alloc = BlockAllocator::new(self.alloc.num_pages(), self.alloc.page_size());
        self.prefix = PrefixCache::new();
        self.seqs.clear();
    }

    /// The i32 block-table row for an executable call, padded with the
    /// garbage page 0 to `max_pages_per_seq`.
    pub fn block_table_row(&self, id: SeqId) -> Vec<i32> {
        let mut row = vec![0i32; self.max_pages_per_seq];
        self.write_block_table_row(id, &mut row);
        row
    }

    /// Allocation-free variant for the decode hot path: write the row for
    /// `id` into `out` (length `max_pages_per_seq`), padding with the
    /// garbage page 0.
    pub fn write_block_table_row(&self, id: SeqId, out: &mut [i32]) {
        debug_assert_eq!(out.len(), self.max_pages_per_seq);
        let seq = &self.seqs[&id];
        // Hard assert (release too): truncating real pages would silently
        // drop attention context, which is worse than the panic the
        // pre-refactor out-of-bounds write produced.
        assert!(
            seq.block_table.len() <= out.len(),
            "sequence {id} holds {} pages > max_pages_per_seq {}",
            seq.block_table.len(),
            out.len()
        );
        for (o, &p) in out.iter_mut().zip(&seq.block_table) {
            *o = p as i32;
        }
        // Pad only the suffix with the garbage page (the prefix was just
        // written; callers may hand us a non-zeroed buffer).
        out[seq.block_table.len()..].fill(0);
    }

    fn sync_evictions(&mut self) {
        for page in self.alloc.take_evicted() {
            self.prefix.forget_page(page);
        }
    }

    #[cfg(test)]
    pub fn allocator(&self) -> &BlockAllocator {
        &self.alloc
    }

    #[cfg(test)]
    pub fn check_invariants(&self) {
        self.alloc.check_invariants();
        // Every live sequence's table pages have refcount >= 1.
        for seq in self.seqs.values() {
            for &p in &seq.block_table {
                assert!(self.alloc.refcount(p) >= 1, "live page {p} unreferenced");
            }
            let ps = self.alloc.page_size();
            let needed = if seq.tokens.is_empty() {
                0
            } else {
                (seq.tokens.len() + ps - 1) / ps
            };
            assert!(seq.block_table.len() >= needed, "table too short");
        }
    }
}
