//! Sequence state + the manager tying allocator and prefix cache together.

use super::{AllocError, BlockAllocator, PrefixCache};
use super::prefix::{page_key, PageKey};
use std::collections::HashMap;

pub type SeqId = u64;

/// One live sequence's KV residency.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub id: SeqId,
    /// All tokens in context (prompt + generated).
    pub tokens: Vec<u32>,
    /// Pages backing positions [0, tokens.len()), in order.
    pub block_table: Vec<u32>,
    /// How many leading tokens were served from the prefix cache.
    pub cached_tokens: usize,
    /// Positions `[0, written)` are resident in the backend page pool
    /// (reused from the prefix cache, prefilled, or written by a decode
    /// step). Trailing tokens past this point have been *sampled* but
    /// not yet written back. Maintained via
    /// [`KvCacheManager::note_written`].
    written: usize,
    /// Keys of the full pages backing this sequence (parallel prefix of
    /// block_table), used to register pages on free.
    page_keys: Vec<PageKey>,
}

impl Sequence {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Pool-resident length: positions `[0, written)` hold real KV.
    pub fn written(&self) -> usize {
        self.written
    }

    /// First prompt position whose logits must actually be computed: the
    /// prefix-cache boundary (`cached_tokens` leading tokens are already
    /// resident in reused pages), clamped so the *final* prompt token is
    /// always computed — its logits seed the first sampled token, so
    /// even a fully-cached prompt pays for exactly one position.
    pub fn prefill_start(&self) -> usize {
        self.cached_tokens.min(self.len().saturating_sub(1))
    }
}

/// Metadata manager for one model's page pool.
pub struct KvCacheManager {
    alloc: BlockAllocator,
    prefix: PrefixCache,
    seqs: HashMap<SeqId, Sequence>,
    max_pages_per_seq: usize,
    enable_prefix_cache: bool,
    /// Whether the backend can physically copy a page (so CoW queues a
    /// copy instead of clamping `written` and recomputing the tail).
    cow_copy: bool,
    /// Queued `(src, dst)` page copies the backend must apply before its
    /// next KV write — fork tail copies and CoW un-shares.
    pending_copies: Vec<(u32, u32)>,
}

impl KvCacheManager {
    pub fn new(
        num_pages: usize,
        page_size: usize,
        max_pages_per_seq: usize,
        enable_prefix_cache: bool,
    ) -> Self {
        Self {
            alloc: BlockAllocator::new(num_pages, page_size),
            prefix: PrefixCache::new(),
            seqs: HashMap::new(),
            max_pages_per_seq,
            enable_prefix_cache,
            cow_copy: false,
            pending_copies: Vec::new(),
        }
    }

    /// Enable queueing physical page copies for fork tails and CoW.
    /// Called at engine init when the backend implements
    /// `ModelBackend::copy_page`; without it the manager clamps `written`
    /// instead and the engine's flush path recomputes the lost positions
    /// (exact by the benign-rewrite property, just slower).
    pub fn set_page_copy(&mut self, enabled: bool) {
        self.cow_copy = enabled;
    }

    /// Drain the queued `(src, dst)` page copies. The engine must apply
    /// each via the backend's page-copy primitive *before* its next
    /// model call — the destination pages are already in live block
    /// tables.
    pub fn take_pending_copies(&mut self) -> Vec<(u32, u32)> {
        std::mem::take(&mut self.pending_copies)
    }

    pub fn page_size(&self) -> usize {
        self.alloc.page_size()
    }

    pub fn max_pages_per_seq(&self) -> usize {
        self.max_pages_per_seq
    }

    pub fn available_pages(&self) -> usize {
        self.alloc.available()
    }

    pub fn prefix_stats(&self) -> (u64, u64) {
        self.prefix.stats()
    }

    pub fn num_sequences(&self) -> usize {
        self.seqs.len()
    }

    pub fn get(&self, id: SeqId) -> Option<&Sequence> {
        self.seqs.get(&id)
    }

    /// Pages needed to admit a prompt of `n` tokens plus one decode slot,
    /// ignoring possible prefix hits (conservative admission bound).
    pub fn pages_needed(&self, n_tokens: usize) -> usize {
        let ps = self.alloc.page_size();
        (n_tokens + 1 + ps - 1) / ps
    }

    /// Whether a prompt of `n` tokens fits right now.
    pub fn can_admit(&self, n_tokens: usize) -> bool {
        let need = self.pages_needed(n_tokens);
        need <= self.max_pages_per_seq && need <= self.alloc.available()
    }

    /// Pages needed to admit an `n_branches`-way fork family over a
    /// prompt of `n_tokens`: one full allocation for the parent plus,
    /// per extra branch, only the pages past the shared full-page
    /// boundary (the fork shares everything else by refcount).
    pub fn pages_needed_family(&self, n_tokens: usize, n_branches: usize) -> usize {
        let ps = self.alloc.page_size();
        let base = self.pages_needed(n_tokens);
        let tail = base - n_tokens / ps;
        base + n_branches.saturating_sub(1) * tail
    }

    /// Whether an `n_branches`-way family over an `n_tokens` prompt fits
    /// right now (conservative: ignores possible prefix hits).
    pub fn can_admit_family(&self, n_tokens: usize, n_branches: usize) -> bool {
        self.pages_needed(n_tokens) <= self.max_pages_per_seq
            && self.pages_needed_family(n_tokens, n_branches) <= self.alloc.available()
    }

    /// Pages currently shared (refcount > 1) across live sequences —
    /// forked families plus live prefix-cache hits. A gauge.
    pub fn shared_pages(&self) -> usize {
        self.alloc.num_shared()
    }

    /// Allocate residency for a new sequence over `tokens` (the prompt).
    /// Serves full-page prefixes from the prefix cache where possible.
    /// Returns the sequence; `cached_tokens` says how many leading tokens
    /// need no prefill compute — the scheduler starts its first
    /// positioned chunk at [`Sequence::prefill_start`], so reused pages
    /// are never recomputed (their contents are read straight through
    /// the block table by the backend's chunk attention).
    pub fn admit(&mut self, id: SeqId, tokens: &[u32]) -> Result<&Sequence, AllocError> {
        assert!(!self.seqs.contains_key(&id), "sequence {id} already admitted");
        let ps = self.alloc.page_size();
        let n_pages = self.pages_needed(tokens.len());
        if n_pages > self.max_pages_per_seq {
            return Err(AllocError::SeqLimit);
        }

        let mut block_table = Vec::with_capacity(n_pages);
        let mut page_keys: Vec<PageKey> = Vec::new();
        let mut cached_tokens = 0usize;

        let full_pages = tokens.len() / ps;
        let mut parent: Option<PageKey> = None;
        let mut reusing = self.enable_prefix_cache;

        // Pass 1: reuse cached full pages while the chain matches.
        for p in 0..full_pages {
            if !reusing {
                break;
            }
            let key = page_key(parent, &tokens[p * ps..(p + 1) * ps]);
            match self.prefix.lookup(key) {
                Some(page) => {
                    self.alloc.retain(page);
                    block_table.push(page);
                    page_keys.push(key);
                    parent = Some(key);
                    cached_tokens += ps;
                }
                None => {
                    reusing = false;
                }
            }
        }

        // Pass 2: fresh pages for the remainder (compute keys as we go so
        // the pages can be registered for future reuse on free).
        let rollback = |alloc: &mut BlockAllocator,
                            prefix: &mut PrefixCache,
                            table: &[u32],
                            keys: &[PageKey]| {
            for (i, &page) in table.iter().enumerate() {
                let keep = i < keys.len() && prefix.contains_page(page);
                alloc.release(page, keep);
            }
        };

        while block_table.len() < n_pages {
            match self.alloc.alloc() {
                Ok(page) => {
                    let idx = block_table.len();
                    if idx < full_pages && self.enable_prefix_cache {
                        let key = page_key(parent, &tokens[idx * ps..(idx + 1) * ps]);
                        page_keys.push(key);
                        parent = Some(key);
                    }
                    block_table.push(page);
                }
                Err(e) => {
                    rollback(&mut self.alloc, &mut self.prefix, &block_table, &page_keys);
                    self.sync_evictions();
                    return Err(e);
                }
            }
        }
        self.sync_evictions();

        let seq = Sequence {
            id,
            tokens: tokens.to_vec(),
            block_table,
            cached_tokens,
            // Reused pages already hold their tokens; everything else is
            // resident only once the engine reports prefill/decode
            // progress through `note_written`.
            written: cached_tokens,
            page_keys,
        };
        Ok(self.seqs.entry(id).or_insert(seq))
    }

    /// Fork `parent` into a new sequence `child` that shares its KV:
    /// every fully-*written* full page is shared by bumping its refcount
    /// (no compute, no copy); each tail page holding partial content is
    /// given to the child as a fresh page — physically copied via the
    /// pending-copy queue when the backend has a page-copy primitive,
    /// otherwise left for the engine's flush path to recompute (the
    /// child's `written` clamps to the shared boundary). A fork
    /// therefore costs O(tail) pages instead of O(context), which is
    /// what makes `n>1` parallel sampling prefill once. Writes by
    /// either side into a still-shared page trigger copy-on-write in
    /// [`Self::append_token`] / [`Self::reserve`]. On `OutOfPages`
    /// everything is rolled back and the parent is untouched.
    pub fn fork(&mut self, parent: SeqId, child: SeqId) -> Result<(), AllocError> {
        assert!(!self.seqs.contains_key(&child), "sequence {child} already admitted");
        let ps = self.alloc.page_size();
        let p = self.seqs.get(&parent).expect("unknown parent sequence");
        let tokens = p.tokens.clone();
        let parent_table = p.block_table.clone();
        let parent_keys = p.page_keys.clone();
        let parent_written = p.written;
        let parent_cached = p.cached_tokens;
        let shared = (parent_written / ps).min(parent_table.len());

        let mut block_table = Vec::with_capacity(parent_table.len());
        let mut queued = 0usize;
        let mut written = parent_written;
        for (i, &page) in parent_table.iter().enumerate() {
            if i < shared {
                self.alloc.retain(page);
                block_table.push(page);
                continue;
            }
            // Tail or reserved-ahead page: the child gets its own copy.
            match self.alloc.alloc() {
                Ok(fresh) => {
                    if parent_written > i * ps {
                        if self.cow_copy {
                            self.pending_copies.push((page, fresh));
                            queued += 1;
                        } else {
                            written = written.min(i * ps);
                        }
                    }
                    block_table.push(fresh);
                }
                Err(e) => {
                    // Roll back: drop this fork's queued copies and
                    // return every page taken so far (shared pages just
                    // lose the child's ref and stay with the parent).
                    self.pending_copies.truncate(self.pending_copies.len() - queued);
                    for &pg in &block_table {
                        let keep = self.prefix.contains_page(pg);
                        self.alloc.release(pg, keep);
                    }
                    self.sync_evictions();
                    return Err(e);
                }
            }
        }
        self.sync_evictions();
        let seq = Sequence {
            id: child,
            tokens,
            block_table,
            cached_tokens: parent_cached.min(written),
            written,
            // Keys hash token content, which the branches share; the
            // clone keeps the child's pages registrable on free.
            page_keys: parent_keys,
        };
        self.seqs.insert(child, seq);
        Ok(())
    }

    /// Record that the backend has materialized positions `[0, upto)` of
    /// sequence `id` in the page pool (a prefill chunk landed, or a
    /// decode step wrote its token). Monotonic; positions never become
    /// unwritten. Only fully-written pages are registered in the prefix
    /// cache on [`Self::free`] — chunked prefill *reads* reused pages
    /// instead of rewriting them, so a page with an unwritten slot (e.g.
    /// from a request aborted mid-prefill) must never be offered for
    /// reuse.
    pub fn note_written(&mut self, id: SeqId, upto: usize) {
        if let Some(seq) = self.seqs.get_mut(&id) {
            debug_assert!(upto <= seq.tokens.len(), "written past sequence end");
            if upto > seq.written {
                seq.written = upto;
            }
        }
    }

    /// Record a generated token, growing the block table when the new
    /// position crosses into an unallocated page. If the page that will
    /// hold the new position is shared with a forked sibling (refcount
    /// > 1), it is un-shared first — copy-on-write — so the upcoming
    /// decode write cannot corrupt the sibling's context.
    pub fn append_token(&mut self, id: SeqId, token: u32) -> Result<(), AllocError> {
        let ps = self.alloc.page_size();
        let seq = self.seqs.get_mut(&id).expect("unknown sequence");
        let pos = seq.tokens.len();
        let page_idx = pos / ps;
        if page_idx >= self.max_pages_per_seq {
            return Err(AllocError::SeqLimit);
        }
        if page_idx >= seq.block_table.len() {
            let page = self.alloc.alloc()?;
            seq.block_table.push(page);
        } else if self.alloc.refcount(seq.block_table[page_idx]) > 1 {
            Self::cow_page(
                &mut self.alloc,
                &self.prefix,
                &mut self.pending_copies,
                self.cow_copy,
                seq,
                page_idx,
            )?;
        }
        seq.tokens.push(token);
        self.sync_evictions();
        Ok(())
    }

    /// Give `seq` an exclusive copy of block-table slot `page_idx`,
    /// whose current page is shared (refcount > 1). With a backend
    /// page-copy primitive the old contents are queued for a physical
    /// copy; without one, `written` clamps to the page boundary and the
    /// engine's flush path recomputes the lost positions (exact by the
    /// benign-rewrite property: re-materializing the same tokens at the
    /// same positions writes identical KV).
    fn cow_page(
        alloc: &mut BlockAllocator,
        prefix: &PrefixCache,
        pending: &mut Vec<(u32, u32)>,
        cow_copy: bool,
        seq: &mut Sequence,
        page_idx: usize,
    ) -> Result<(), AllocError> {
        let ps = alloc.page_size();
        let old = seq.block_table[page_idx];
        let fresh = alloc.alloc()?;
        if seq.written > page_idx * ps {
            if cow_copy {
                pending.push((old, fresh));
            } else {
                seq.written = page_idx * ps;
                seq.cached_tokens = seq.cached_tokens.min(seq.written);
            }
        }
        // The old page stays alive through its other holders; `release`
        // only parks/frees at refcount zero.
        alloc.release(old, prefix.contains_page(old));
        seq.block_table[page_idx] = fresh;
        Ok(())
    }

    /// Grow `id`'s block table until it covers positions `[0, upto)`,
    /// without appending tokens — speculative verification writes a
    /// draft run's KV *before* knowing which tokens will be accepted, so
    /// the pages must exist up front. Already-covering tables are a
    /// no-op. On `OutOfPages` the pages allocated so far are kept (they
    /// are released by `free`/`truncate` like any other page).
    pub fn reserve(&mut self, id: SeqId, upto: usize) -> Result<(), AllocError> {
        let ps = self.alloc.page_size();
        let need = (upto + ps - 1) / ps;
        if need > self.max_pages_per_seq {
            return Err(AllocError::SeqLimit);
        }
        let mut result = Ok(());
        let seq = self.seqs.get_mut(&id).expect("unknown sequence");
        // Verification writes positions [len-1, upto); an existing page
        // overlapping that range that is still shared with a forked
        // sibling must be un-shared before the backend writes into it.
        // (Unreachable for current fork families — their write range is
        // exclusive by construction — so only the copy-capable path
        // bothers; the recompute fallback would leave the verify read
        // window unwritten.)
        if self.cow_copy {
            let first_write = seq.tokens.len().saturating_sub(1) / ps;
            for idx in first_write..seq.block_table.len().min(need) {
                if self.alloc.refcount(seq.block_table[idx]) > 1 {
                    if let Err(e) = Self::cow_page(
                        &mut self.alloc,
                        &self.prefix,
                        &mut self.pending_copies,
                        true,
                        seq,
                        idx,
                    ) {
                        result = Err(e);
                        break;
                    }
                }
            }
        }
        while result.is_ok() && seq.block_table.len() < need {
            match self.alloc.alloc() {
                Ok(page) => seq.block_table.push(page),
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
        self.sync_evictions();
        result
    }

    /// Drop `id`'s tokens past `new_len`, releasing pages wholly beyond
    /// the shortened sequence. Used to roll a speculative mirror back to
    /// the accepted prefix after a draft rejection. `written` and
    /// `cached_tokens` clamp down with the tokens: rejected positions
    /// become unwritten again, so they can neither be attended over nor
    /// registered for prefix reuse. No-op if the sequence is unknown or
    /// already short enough.
    pub fn truncate(&mut self, id: SeqId, new_len: usize) {
        let ps = self.alloc.page_size();
        let Some(seq) = self.seqs.get_mut(&id) else { return };
        if new_len >= seq.tokens.len() {
            return;
        }
        seq.tokens.truncate(new_len);
        let keep_pages = (new_len + ps - 1) / ps;
        let mut popped = Vec::new();
        while seq.block_table.len() > keep_pages {
            popped.push(seq.block_table.pop().unwrap());
        }
        // Keys address *full* pages of the old token vector; only pages
        // still fully backed by surviving tokens keep their keys.
        seq.page_keys.truncate(new_len / ps);
        if seq.written > new_len {
            seq.written = new_len;
        }
        if seq.cached_tokens > new_len {
            seq.cached_tokens = new_len;
        }
        for page in popped {
            // A popped page can still be alive as another sequence's
            // prefix hit; `release` only parks/frees at refcount zero.
            let keep = self.prefix.contains_page(page);
            self.alloc.release(page, keep);
            self.purge_dead_copies(page);
        }
        self.sync_evictions();
    }

    /// Free a sequence. Fully *written* pages (with computed keys) are
    /// registered in the prefix cache and parked evictable; the rest
    /// return to the free list. The `written` bound keeps pages with
    /// unwritten slots — a prompt aborted mid-prefill, or the final
    /// sampled-but-never-decoded token — out of the reuse pool.
    pub fn free(&mut self, id: SeqId) {
        let Some(mut seq) = self.seqs.remove(&id) else { return };
        let ps = self.alloc.page_size();
        let full_pages = seq.tokens.len().min(seq.written) / ps;
        if self.enable_prefix_cache {
            // Keys may be missing for pages past the originally-hashed
            // prompt prefix (tokens generated later). Compute them
            // lazily *and chain them*: each computed key becomes the
            // next page's parent, so a whole decoded suffix re-enters
            // the cache warm — the preempted-victim resume path skips
            // every fully-written page, not just the first one.
            while seq.page_keys.len() < full_pages {
                let i = seq.page_keys.len();
                let parent = if i == 0 { None } else { Some(seq.page_keys[i - 1]) };
                seq.page_keys.push(page_key(parent, &seq.tokens[i * ps..(i + 1) * ps]));
            }
        }
        for (i, &page) in seq.block_table.iter().enumerate() {
            let mut keep = false;
            if self.enable_prefix_cache && i < full_pages {
                let key = seq.page_keys[i];
                // Register only sole-owner pages: a forked sibling still
                // holds shared pages live, and the *last* branch to free
                // is the one that parks them for future reuse.
                if key != 0 && self.alloc.refcount(page) == 1 {
                    self.prefix.insert(key, page);
                    keep = self.prefix.contains_page(page);
                }
            }
            // Shared pages stay alive through other sequences' refs.
            let keep = keep || self.prefix.contains_page(page);
            self.alloc.release(page, keep);
            self.purge_dead_copies(page);
        }
        self.sync_evictions();
    }

    /// Drop pending copies touching a page that just hit refcount zero:
    /// a freed page can be re-allocated and rewritten before the engine
    /// drains the queue, so a stale copy would clobber (dst) or leak
    /// garbage from (src) an unrelated sequence.
    fn purge_dead_copies(&mut self, page: u32) {
        if self.alloc.refcount(page) == 0 && !self.pending_copies.is_empty() {
            self.pending_copies.retain(|&(s, d)| s != page && d != page);
        }
    }

    /// Discard ALL pool state — allocator, prefix cache, and every live
    /// sequence — after device loss. The physical pages backing them are
    /// gone with the device, so the usual [`Self::free`] path (which
    /// parks fully-written pages for prefix reuse) would serve garbage
    /// KV to future admissions; nothing may survive. Prefix hit/miss
    /// counters reset with the cache.
    pub fn invalidate_all(&mut self) {
        self.alloc = BlockAllocator::new(self.alloc.num_pages(), self.alloc.page_size());
        self.prefix = PrefixCache::new();
        self.seqs.clear();
        // Queued copies referenced pages on the lost device.
        self.pending_copies.clear();
    }

    /// The i32 block-table row for an executable call, padded with the
    /// garbage page 0 to `max_pages_per_seq`.
    pub fn block_table_row(&self, id: SeqId) -> Vec<i32> {
        let mut row = vec![0i32; self.max_pages_per_seq];
        self.write_block_table_row(id, &mut row);
        row
    }

    /// Allocation-free variant for the decode hot path: write the row for
    /// `id` into `out` (length `max_pages_per_seq`), padding with the
    /// garbage page 0.
    pub fn write_block_table_row(&self, id: SeqId, out: &mut [i32]) {
        debug_assert_eq!(out.len(), self.max_pages_per_seq);
        let seq = &self.seqs[&id];
        // Hard assert (release too): truncating real pages would silently
        // drop attention context, which is worse than the panic the
        // pre-refactor out-of-bounds write produced.
        assert!(
            seq.block_table.len() <= out.len(),
            "sequence {id} holds {} pages > max_pages_per_seq {}",
            seq.block_table.len(),
            out.len()
        );
        for (o, &p) in out.iter_mut().zip(&seq.block_table) {
            *o = p as i32;
        }
        // Pad only the suffix with the garbage page (the prefix was just
        // written; callers may hand us a non-zeroed buffer).
        out[seq.block_table.len()..].fill(0);
    }

    fn sync_evictions(&mut self) {
        for page in self.alloc.take_evicted() {
            self.prefix.forget_page(page);
        }
    }

    #[cfg(test)]
    pub fn allocator(&self) -> &BlockAllocator {
        &self.alloc
    }

    #[cfg(test)]
    pub fn check_invariants(&self) {
        self.alloc.check_invariants();
        // Pending copies must reference live pages only (purged on free).
        for &(s, d) in &self.pending_copies {
            assert!(self.alloc.refcount(s) >= 1, "pending copy src {s} dead");
            assert!(self.alloc.refcount(d) >= 1, "pending copy dst {d} dead");
        }
        // Every live sequence's table pages have refcount >= 1.
        for seq in self.seqs.values() {
            for &p in &seq.block_table {
                assert!(self.alloc.refcount(p) >= 1, "live page {p} unreferenced");
            }
            let ps = self.alloc.page_size();
            let needed = if seq.tokens.is_empty() {
                0
            } else {
                (seq.tokens.len() + ps - 1) / ps
            };
            assert!(seq.block_table.len() >= needed, "table too short");
        }
    }
}
