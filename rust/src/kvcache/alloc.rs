//! Reference-counted page allocator over the fixed device pool.

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AllocError {
    /// No free or evictable page available. The *pool* is exhausted —
    /// preempting a victim sequence can recover from this.
    OutOfPages,
    /// The request exceeds `max_pages_per_seq` (the per-sequence context
    /// cap). No amount of eviction helps; the scheduler must never
    /// preempt on this variant.
    SeqLimit,
}

impl fmt::Display for AllocError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AllocError::OutOfPages => write!(f, "KV cache out of pages"),
            AllocError::SeqLimit => write!(f, "sequence exceeds max pages per sequence"),
        }
    }
}

impl std::error::Error for AllocError {}

/// Allocator over pages `1..num_pages` (page 0 is the garbage page).
///
/// Pages are in one of three states:
///   * free       — on the free list
///   * active     — refcount > 0 (owned by >= 1 sequence)
///   * cached     — refcount == 0 but retained for prefix reuse; evictable
///     in LRU order when the free list runs dry.
pub struct BlockAllocator {
    page_size: usize,
    num_pages: usize,
    refcount: Vec<u32>,
    free: Vec<u32>,
    /// Cached (evictable) pages in LRU order: front = oldest.
    lru: Vec<u32>,
    /// Eviction callback target: the prefix cache drops its entry.
    evicted: Vec<u32>,
}

impl BlockAllocator {
    pub fn new(num_pages: usize, page_size: usize) -> Self {
        assert!(num_pages >= 2, "need at least the garbage page + 1");
        Self {
            page_size,
            num_pages,
            refcount: vec![0; num_pages],
            // Hand out low page ids first (nicer to read in tests/logs).
            free: (1..num_pages as u32).rev().collect(),
            lru: Vec::new(),
            evicted: Vec::new(),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn num_pages(&self) -> usize {
        self.num_pages
    }

    /// Pages that can be handed out right now (free + evictable).
    pub fn available(&self) -> usize {
        self.free.len() + self.lru.len()
    }

    pub fn num_free(&self) -> usize {
        self.free.len()
    }

    pub fn num_cached(&self) -> usize {
        self.lru.len()
    }

    pub fn refcount(&self, page: u32) -> u32 {
        self.refcount[page as usize]
    }

    /// Pages held by more than one owner right now — forked-family
    /// shares plus live prefix-cache hits. A gauge, not a counter.
    pub fn num_shared(&self) -> usize {
        self.refcount.iter().filter(|&&rc| rc > 1).count()
    }

    /// Allocate a fresh page (refcount 1), evicting the LRU cached page
    /// if the free list is empty. Evicted page ids are queued for the
    /// prefix cache to unmap (`take_evicted`).
    pub fn alloc(&mut self) -> Result<u32, AllocError> {
        let page = if let Some(p) = self.free.pop() {
            p
        } else if !self.lru.is_empty() {
            let p = self.lru.remove(0);
            self.evicted.push(p);
            p
        } else {
            return Err(AllocError::OutOfPages);
        };
        debug_assert_eq!(self.refcount[page as usize], 0);
        self.refcount[page as usize] = 1;
        Ok(page)
    }

    /// Add a reference (prefix sharing). Valid on active or cached pages;
    /// a cached page becomes active again.
    pub fn retain(&mut self, page: u32) {
        let rc = &mut self.refcount[page as usize];
        if *rc == 0 {
            // Revive from the LRU.
            if let Some(idx) = self.lru.iter().position(|&p| p == page) {
                self.lru.remove(idx);
            } else {
                panic!("retain on a free page {page}");
            }
        }
        *rc += 1;
    }

    /// Drop a reference. When the count hits zero the page either parks in
    /// the LRU (if `keep_cached`, i.e. the prefix cache still maps it) or
    /// returns to the free list.
    pub fn release(&mut self, page: u32, keep_cached: bool) {
        let rc = &mut self.refcount[page as usize];
        assert!(*rc > 0, "release on unreferenced page {page}");
        *rc -= 1;
        if *rc == 0 {
            if keep_cached {
                self.lru.push(page);
            } else {
                self.free.push(page);
            }
        }
    }

    /// Pages evicted from the cached set since the last call; the prefix
    /// cache must forget them.
    pub fn take_evicted(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.evicted)
    }

    /// Drop a page from the cached set explicitly (prefix-cache unmap path).
    pub fn drop_cached(&mut self, page: u32) {
        if let Some(idx) = self.lru.iter().position(|&p| p == page) {
            self.lru.remove(idx);
            self.free.push(page);
        }
    }

    /// Invariant check for tests: every page is in exactly one state.
    #[cfg(test)]
    pub fn check_invariants(&self) {
        let mut seen = vec![0u32; self.num_pages];
        for &p in &self.free {
            seen[p as usize] += 1;
            assert_eq!(self.refcount[p as usize], 0, "free page {p} has refs");
        }
        for &p in &self.lru {
            seen[p as usize] += 1;
            assert_eq!(self.refcount[p as usize], 0, "cached page {p} has refs");
        }
        for p in 1..self.num_pages {
            let states = seen[p] + u32::from(self.refcount[p] > 0);
            assert_eq!(states, 1, "page {p} in {states} states");
        }
        assert_eq!(seen[0], 0, "garbage page must never be allocated");
    }
}
