//! Prefix cache: content-addressed full pages for prompt reuse.
//!
//! Keyed vLLM-style: a page's key is the hash of (parent key, the page's
//! token ids). Only *full* pages are cached; the values written by a
//! prefill of the same token prefix are identical, so re-running prefill
//! over shared pages is a benign rewrite (DESIGN.md §3 kvcache/).

use std::collections::HashMap;

pub type PageKey = u64;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Chain hash for a full page of tokens given the previous page's key.
pub fn page_key(parent: Option<PageKey>, tokens: &[u32]) -> PageKey {
    let mut h = FNV_OFFSET ^ parent.unwrap_or(0x9E3779B97F4A7C15);
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Bidirectional map key <-> page id.
#[derive(Default)]
pub struct PrefixCache {
    by_key: HashMap<PageKey, u32>,
    by_page: HashMap<u32, PageKey>,
    hits: u64,
    misses: u64,
}

impl PrefixCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn lookup(&mut self, key: PageKey) -> Option<u32> {
        match self.by_key.get(&key) {
            Some(&p) => {
                self.hits += 1;
                Some(p)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Map a completed full page. A later identical prefix wins the
    /// existing entry; remapping the same key to a new page keeps the old
    /// (first writer wins — both hold identical data).
    pub fn insert(&mut self, key: PageKey, page: u32) {
        if self.by_key.contains_key(&key) {
            return;
        }
        self.by_key.insert(key, page);
        self.by_page.insert(page, key);
    }

    pub fn contains_page(&self, page: u32) -> bool {
        self.by_page.contains_key(&page)
    }

    /// Forget a page (on allocator eviction).
    pub fn forget_page(&mut self, page: u32) {
        if let Some(key) = self.by_page.remove(&page) {
            self.by_key.remove(&key);
        }
    }

    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}
