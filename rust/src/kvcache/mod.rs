//! Paged KV-cache management (the WASM "sequence management" subsystem of
//! the paper, §2.2 — here in native Rust).
//!
//! The device-side page *pool* lives in the model's cache tensors
//! (f32[L, P, page, KVH, Dh], see python/compile/model.py); this module
//! owns the metadata: which pages belong to which sequence, reference
//! counts for prefix sharing, and the free list. The scheduler consults
//! it for admission control; the runtime turns block tables into the i32
//! tensors the decode/prefill executables consume.
//!
//! Page 0 is reserved as the garbage page — padding slots in batched
//! decode write there (same convention as the L2 model).

mod alloc;
mod prefix;
mod seq;

pub use alloc::{AllocError, BlockAllocator};
pub use prefix::PrefixCache;
pub use seq::{KvCacheManager, SeqId, Sequence};

#[cfg(test)]
mod tests;
