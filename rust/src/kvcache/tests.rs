use super::prefix::page_key;
use super::*;
use crate::testutil::prop::Runner;

#[test]
fn alloc_exhaustion_and_reuse() {
    let mut a = BlockAllocator::new(4, 8); // pages 1..3 usable
    let p1 = a.alloc().unwrap();
    let p2 = a.alloc().unwrap();
    let p3 = a.alloc().unwrap();
    assert_eq!(a.alloc(), Err(AllocError::OutOfPages));
    a.release(p2, false);
    assert_eq!(a.alloc().unwrap(), p2);
    a.check_invariants();
    assert!(p1 != p3 && p1 > 0 && p2 > 0 && p3 > 0);
}

#[test]
fn cached_pages_evict_lru() {
    let mut a = BlockAllocator::new(4, 8);
    let p1 = a.alloc().unwrap();
    let p2 = a.alloc().unwrap();
    let _p3 = a.alloc().unwrap();
    a.release(p1, true); // cached, oldest
    a.release(p2, true);
    assert_eq!(a.num_cached(), 2);
    let got = a.alloc().unwrap();
    assert_eq!(got, p1, "LRU eviction order");
    assert_eq!(a.take_evicted(), vec![p1]);
    a.check_invariants();
}

#[test]
fn retain_revives_cached_page() {
    let mut a = BlockAllocator::new(4, 8);
    let p = a.alloc().unwrap();
    a.release(p, true);
    a.retain(p);
    assert_eq!(a.refcount(p), 1);
    assert_eq!(a.num_cached(), 0);
    a.check_invariants();
}

#[test]
#[should_panic(expected = "release on unreferenced")]
fn double_release_panics() {
    let mut a = BlockAllocator::new(4, 8);
    let p = a.alloc().unwrap();
    a.release(p, false);
    a.release(p, false);
}

#[test]
fn page_key_chains() {
    let k1 = page_key(None, &[1, 2, 3]);
    let k2 = page_key(Some(k1), &[4, 5, 6]);
    let k2b = page_key(Some(k1), &[4, 5, 7]);
    let k2c = page_key(None, &[4, 5, 6]);
    assert_ne!(k2, k2b);
    assert_ne!(k2, k2c, "same tokens, different parent");
    assert_eq!(page_key(None, &[1, 2, 3]), k1);
}

#[test]
fn manager_admit_and_free_roundtrip() {
    let mut m = KvCacheManager::new(16, 4, 8, true);
    let seq = m.admit(1, &[10, 11, 12, 13, 14]).unwrap();
    assert_eq!(seq.block_table.len(), 2); // ceil((5+1)/4)
    assert_eq!(seq.cached_tokens, 0);
    m.check_invariants();
    m.free(1);
    m.check_invariants();
    assert_eq!(m.num_sequences(), 0);
}

#[test]
fn prefix_reuse_after_free() {
    let mut m = KvCacheManager::new(16, 4, 8, true);
    let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8, 9]; // two full pages + 1
    let t1 = m.admit(1, &prompt).unwrap().block_table.clone();
    m.note_written(1, prompt.len()); // prefill landed
    m.free(1);
    let seq2 = m.admit(2, &prompt).unwrap();
    // the two full pages come back from the prefix cache
    assert_eq!(seq2.cached_tokens, 8);
    assert_eq!(&seq2.block_table[..2], &t1[..2]);
    let (hits, _) = m.prefix_stats();
    assert_eq!(hits, 2);
    m.check_invariants();
}

#[test]
fn prefix_sharing_between_live_sequences() {
    let mut m = KvCacheManager::new(16, 4, 8, true);
    let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
    m.admit(1, &prompt).unwrap();
    m.note_written(1, prompt.len());
    m.free(1); // registers both (written) pages
    m.admit(2, &prompt).unwrap();
    let t2 = m.get(2).unwrap().block_table.clone();
    m.admit(3, &prompt).unwrap();
    let t3 = m.get(3).unwrap().block_table.clone();
    assert_eq!(t2[..2], t3[..2], "live sequences share prefix pages");
    assert_eq!(m.allocator().refcount(t2[0]), 2);
    m.free(2);
    assert_eq!(m.allocator().refcount(t2[0]), 1);
    m.check_invariants();
    m.free(3);
    m.check_invariants();
}

#[test]
fn divergent_prefix_stops_reuse() {
    let mut m = KvCacheManager::new(16, 4, 8, true);
    m.admit(1, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
    m.note_written(1, 8);
    m.free(1);
    let seq = m.admit(2, &[1, 2, 3, 4, 9, 9, 9, 9]).unwrap();
    assert_eq!(seq.cached_tokens, 4, "only the first page matches");
    m.check_invariants();
}

#[test]
fn unwritten_pages_are_never_registered_for_reuse() {
    // Mid-prefill abort shape: a sequence freed before any (or all) of
    // its prompt landed in the pool must not poison the prefix cache —
    // chunked prefill would *read* the reused pages, hitting slots that
    // were never written.
    let mut m = KvCacheManager::new(16, 4, 8, true);
    let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12];

    // Freed with nothing written: zero pages registered.
    m.admit(1, &prompt).unwrap();
    m.free(1);
    let seq = m.admit(2, &prompt).unwrap();
    assert_eq!(seq.cached_tokens, 0, "unwritten pages must not be reused");

    // Freed with one of three full pages written: only that page comes back.
    m.note_written(2, 5); // page 0 fully written, page 1 partial
    m.free(2);
    let seq = m.admit(3, &prompt).unwrap();
    assert_eq!(seq.cached_tokens, 4, "only the fully-written page is reusable");
    assert_eq!(seq.written(), 4, "reused pages count as resident");
    assert_eq!(seq.prefill_start(), 4);
    m.check_invariants();
}

#[test]
fn prefill_start_clamps_to_last_prompt_token() {
    let mut m = KvCacheManager::new(16, 4, 8, true);
    let prompt = [7u32, 8, 9, 10, 11, 12, 13, 14]; // exactly two pages
    m.admit(1, &prompt).unwrap();
    m.note_written(1, 8);
    m.free(1);
    // Fully-cached prompt: everything resident, but the final position
    // must still be computed for its logits.
    let seq = m.admit(2, &prompt).unwrap();
    assert_eq!(seq.cached_tokens, 8);
    assert_eq!(seq.prefill_start(), 7);
}

#[test]
fn append_token_grows_table_on_page_boundary() {
    let mut m = KvCacheManager::new(16, 4, 8, false);
    m.admit(1, &[1, 2, 3]).unwrap(); // 3 prompt tokens + 1 slot = 1 page
    assert_eq!(m.get(1).unwrap().block_table.len(), 1);
    m.append_token(1, 40).unwrap(); // pos 3, fits page 0
    assert_eq!(m.get(1).unwrap().block_table.len(), 1);
    m.append_token(1, 41).unwrap(); // pos 4 -> page 1 allocated
    assert_eq!(m.get(1).unwrap().block_table.len(), 2);
    m.check_invariants();
}

#[test]
fn append_token_respects_max_pages() {
    let mut m = KvCacheManager::new(64, 4, 2, false);
    m.admit(1, &[1, 2, 3, 4, 5, 6, 7]).unwrap(); // 7 tokens: 2 pages
    m.append_token(1, 8).unwrap(); // pos 7 fills page 2
    // Per-sequence cap, not pool exhaustion: preemption must not trigger.
    assert_eq!(m.append_token(1, 9), Err(AllocError::SeqLimit));
}

#[test]
fn truncate_rolls_back_tokens_pages_and_written() {
    let mut m = KvCacheManager::new(16, 4, 8, false);
    m.admit(1, &[1, 2, 3, 4, 5]).unwrap(); // 2 pages
    m.note_written(1, 5);
    for t in [6u32, 7, 8, 9] {
        m.append_token(1, t).unwrap(); // grows to 9 tokens, 3 pages
    }
    m.note_written(1, 9);
    assert_eq!(m.get(1).unwrap().block_table.len(), 3);
    let free_before = m.available_pages();

    m.truncate(1, 5); // drop the speculative suffix
    let seq = m.get(1).unwrap();
    assert_eq!(seq.tokens, vec![1, 2, 3, 4, 5]);
    assert_eq!(seq.block_table.len(), 2);
    assert_eq!(seq.written(), 5, "rejected positions become unwritten");
    assert_eq!(m.available_pages(), free_before + 1);
    m.check_invariants();

    // Truncate to a no-op length: nothing changes.
    m.truncate(1, 9);
    assert_eq!(m.get(1).unwrap().len(), 5);

    // The sequence keeps working: appends re-grow the table lazily.
    for t in [20u32, 21, 22, 23] {
        m.append_token(1, t).unwrap();
    }
    assert_eq!(m.get(1).unwrap().block_table.len(), 3);
    m.check_invariants();
    m.free(1);
    m.check_invariants();
}

#[test]
fn truncated_suffix_is_never_registered_for_reuse() {
    // Speculative-rejection shape: tokens written into the pool, then
    // rolled back. A later free must not offer the rolled-back pages'
    // contents for prefix reuse.
    let mut m = KvCacheManager::new(16, 4, 8, true);
    m.admit(1, &[1, 2, 3, 4]).unwrap();
    m.note_written(1, 4);
    for t in [5u32, 6, 7, 8] {
        m.append_token(1, t).unwrap();
    }
    m.note_written(1, 8); // two full "written" pages
    m.truncate(1, 4); // reject the second page's worth
    m.free(1);
    let seq = m.admit(2, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
    assert_eq!(seq.cached_tokens, 4, "only the surviving page is reusable");
    m.check_invariants();
}

#[test]
fn truncate_to_zero_releases_everything() {
    let mut m = KvCacheManager::new(16, 4, 8, false);
    let free0 = m.available_pages();
    m.admit(1, &[1, 2, 3, 4, 5, 6, 7, 8, 9]).unwrap();
    m.note_written(1, 9);
    m.truncate(1, 0);
    let seq = m.get(1).unwrap();
    assert!(seq.is_empty());
    assert_eq!(seq.block_table.len(), 0);
    assert_eq!(seq.written(), 0);
    assert_eq!(m.available_pages(), free0);
    m.check_invariants();
    m.free(1);
    m.check_invariants();
}

#[test]
fn reserve_grows_table_without_tokens() {
    let mut m = KvCacheManager::new(16, 4, 4, false);
    m.admit(1, &[1, 2, 3]).unwrap(); // 1 page
    assert_eq!(m.get(1).unwrap().block_table.len(), 1);
    m.reserve(1, 9).unwrap(); // cover positions [0, 9): 3 pages
    assert_eq!(m.get(1).unwrap().block_table.len(), 3);
    m.reserve(1, 2).unwrap(); // already covered: no-op
    assert_eq!(m.get(1).unwrap().block_table.len(), 3);
    assert_eq!(m.reserve(1, 17), Err(AllocError::SeqLimit)); // > max_pages
    m.check_invariants();
    m.free(1);
    m.check_invariants();
}

#[test]
fn reserve_failure_keeps_partial_pages_reclaimable() {
    let mut m = KvCacheManager::new(4, 4, 8, false); // 3 usable pages
    m.admit(1, &[1, 2, 3]).unwrap(); // 1 page
    assert_eq!(m.reserve(1, 16), Err(AllocError::OutOfPages)); // wants 4, pool has 2
    let got = m.get(1).unwrap().block_table.len();
    assert!(got >= 1 && got <= 3);
    m.check_invariants();
    m.free(1);
    m.check_invariants();
    assert_eq!(m.available_pages(), 3, "partial reservation fully reclaimed");
}

#[test]
fn preempt_free_releases_pages_and_preserves_written_prefix_reuse() {
    // Preemption shape: a sequence mid-decode is freed to reclaim its
    // pages, then re-admitted later with the same token vector. Its
    // fully-written full pages must come back as prefix hits (recompute
    // only the uncached suffix), and the freed pages must be genuinely
    // re-allocatable by another sequence in between.
    let mut m = KvCacheManager::new(8, 4, 8, true); // 7 usable pages
    let tokens = [1u32, 2, 3, 4, 5, 6, 7, 8, 9, 10]; // 2 full pages + 2
    m.admit(1, &tokens).unwrap(); // 3 pages
    m.note_written(1, 10);
    let before = m.available_pages();
    m.free(1); // preempt: pages park evictable, full pages registered
    assert_eq!(m.available_pages(), before + 3, "victim pages reclaimable");

    // Another sequence can consume the whole pool (evicting the parked
    // pages if needed)...
    m.admit(2, &[9u32; 26]).unwrap(); // 7 pages: evicts victim pages
    assert_eq!(m.available_pages(), 0);
    m.free(2);

    // ...and resume still works, re-admitting from whatever survived
    // (here: nothing — the interloper evicted everything).
    let seq = m.admit(1, &tokens).unwrap();
    assert!(seq.cached_tokens <= 8);
    assert_eq!(seq.written(), seq.cached_tokens);
    m.check_invariants();
    m.free(1);

    // Without an interloper, resume gets full-page prefix hits.
    m.admit(3, &tokens).unwrap();
    m.note_written(3, 10);
    m.free(3);
    let seq = m.admit(4, &tokens).unwrap();
    assert_eq!(seq.cached_tokens, 8, "written full pages reused on resume");
    assert_eq!(seq.prefill_start(), 8);
    m.check_invariants();
}

#[test]
fn admission_control_bounds() {
    let m = KvCacheManager::new(8, 4, 4, false); // 7 usable pages
    assert!(m.can_admit(12));
    assert!(!m.can_admit(16)); // needs 5 pages > max_pages_per_seq 4
    let mut m2 = KvCacheManager::new(4, 4, 4, false); // 3 usable
    assert!(m2.can_admit(8));
    m2.admit(1, &[0; 8]).unwrap(); // takes 3 pages (8+1 tokens)
    assert!(!m2.can_admit(8));
}

#[test]
fn admit_rolls_back_on_exhaustion() {
    let mut m = KvCacheManager::new(4, 4, 8, true); // 3 usable pages
    m.admit(1, &[1, 2, 3, 4, 5, 6]).unwrap(); // 2 pages
    let err = m.admit(2, &[9; 10]); // needs 3 pages, only 1 left
    assert!(err.is_err());
    m.check_invariants();
    // seq 1 unharmed and pages not leaked
    assert_eq!(m.available_pages(), 1);
    m.free(1);
    m.check_invariants();
    assert_eq!(m.available_pages(), 3);
}

#[test]
fn block_table_row_pads_with_garbage_page() {
    let mut m = KvCacheManager::new(16, 4, 6, false);
    m.admit(7, &[1, 2, 3, 4, 5]).unwrap();
    let row = m.block_table_row(7);
    assert_eq!(row.len(), 6);
    assert!(row[0] > 0 && row[1] > 0);
    assert_eq!(&row[2..], &[0, 0, 0, 0]);
}

#[test]
fn disabled_prefix_cache_never_shares() {
    let mut m = KvCacheManager::new(16, 4, 8, false);
    let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
    m.admit(1, &prompt).unwrap();
    m.note_written(1, prompt.len());
    m.free(1);
    let seq = m.admit(2, &prompt).unwrap();
    assert_eq!(seq.cached_tokens, 0);
    let (hits, misses) = m.prefix_stats();
    assert_eq!((hits, misses), (0, 0));
}

#[test]
fn prop_random_admit_free_append_keeps_invariants() {
    Runner::new("kvcache_invariants", 150).run(|rng| {
        let page_size = *rng.choose(&[4usize, 8, 16]);
        let num_pages = 2 + rng.range(40);
        let max_pages = 1 + rng.range(10);
        let mut m = KvCacheManager::new(num_pages, page_size, max_pages, rng.bool());
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..200 {
            match rng.range(4) {
                0 => {
                    let n = 1 + rng.range(page_size * 3);
                    let toks: Vec<u32> = (0..n).map(|_| rng.range(64) as u32).collect();
                    next_id += 1;
                    if m.admit(next_id, &toks).is_ok() {
                        live.push(next_id);
                    }
                }
                1 if !live.is_empty() => {
                    let idx = rng.range(live.len());
                    let id = live.swap_remove(idx);
                    m.free(id);
                }
                2 if !live.is_empty() => {
                    let id = *rng.choose(&live);
                    let _ = m.append_token(id, rng.range(64) as u32);
                }
                3 if !live.is_empty() => {
                    // Simulate prefill/decode progress reports.
                    let id = *rng.choose(&live);
                    let len = m.get(id).unwrap().len();
                    m.note_written(id, rng.range(len + 1));
                }
                _ => {}
            }
            m.check_invariants();
        }
        for id in live {
            m.free(id);
        }
        m.check_invariants();
        Ok(())
    });
}

#[test]
fn prop_prefix_cache_shared_tables_agree() {
    // Two sequences with a common full-page prefix must end up sharing
    // exactly the common full pages when cache hits occur.
    Runner::new("prefix_sharing", 100).run(|rng| {
        let ps = 4usize;
        let mut m = KvCacheManager::new(64, ps, 16, true);
        let common_pages = 1 + rng.range(3);
        let common: Vec<u32> = (0..common_pages * ps).map(|_| rng.range(32) as u32).collect();
        let mut p1 = common.clone();
        let mut p2 = common.clone();
        p1.extend((0..rng.range(6)).map(|_| 100 + rng.range(32) as u32));
        p2.extend((0..rng.range(6)).map(|_| 200 + rng.range(32) as u32));
        m.admit(1, &p1).unwrap();
        m.note_written(1, p1.len());
        m.free(1); // register prefix
        m.admit(2, &p2).unwrap();
        let seq2 = m.get(2).unwrap();
        if seq2.cached_tokens != common_pages * ps {
            return Err(format!(
                "expected {} cached tokens, got {}",
                common_pages * ps,
                seq2.cached_tokens
            ));
        }
        m.check_invariants();
        Ok(())
    });
}

#[test]
fn family_admission_accounting() {
    let m = KvCacheManager::new(16, 4, 8, false); // 15 usable pages
    // 6 tokens: 2 pages base (7 slots), of which 1 is full -> each extra
    // branch re-allocates only the non-full tail page.
    assert_eq!(m.pages_needed(6), 2);
    assert_eq!(m.pages_needed_family(6, 1), 2);
    assert_eq!(m.pages_needed_family(6, 3), 4);
    assert!(m.can_admit_family(6, 3));
    // 7 tokens fill page 1 exactly only after the +1 decode slot, so
    // every branch still re-allocates one tail page.
    assert_eq!(m.pages_needed_family(7, 4), 5);
    // Family total may exceed the pool even when one branch fits.
    let small = KvCacheManager::new(4, 4, 8, false); // 3 usable
    assert!(small.can_admit(6));
    assert!(!small.can_admit_family(6, 3));
}

#[test]
fn fork_shares_full_pages_and_copies_tail() {
    let mut m = KvCacheManager::new(16, 4, 8, true);
    m.set_page_copy(true);
    let prompt = [1u32, 2, 3, 4, 5, 6]; // page 0 full, page 1 partial
    m.admit(1, &prompt).unwrap();
    m.note_written(1, 6);
    let t1 = m.get(1).unwrap().block_table.clone();
    let avail = m.available_pages();

    m.fork(1, 2).unwrap();
    let t2 = m.get(2).unwrap().block_table.clone();
    assert_eq!(t2[0], t1[0], "full written page is shared");
    assert_eq!(m.allocator().refcount(t1[0]), 2);
    assert_ne!(t2[1], t1[1], "tail page is private per branch");
    assert_eq!(m.allocator().refcount(t1[1]), 1);
    assert_eq!(m.available_pages(), avail - 1, "fork costs exactly the tail");
    assert_eq!(m.shared_pages(), 1);
    // The physical tail copy is queued for the backend, and the child is
    // fully resident (the copy carries the parent's written content).
    assert_eq!(m.take_pending_copies(), vec![(t1[1], t2[1])]);
    assert_eq!(m.get(2).unwrap().written(), 6);
    assert_eq!(m.get(2).unwrap().tokens, prompt);
    m.check_invariants();
    m.free(1);
    m.free(2);
    m.check_invariants();
}

#[test]
fn fork_without_copy_primitive_clamps_written() {
    // No backend page copy: the child's tail page starts unwritten and
    // the engine's flush path recomputes it (benign rewrite).
    let mut m = KvCacheManager::new(16, 4, 8, false);
    m.set_page_copy(false);
    m.admit(1, &[1, 2, 3, 4, 5, 6]).unwrap();
    m.note_written(1, 6);
    m.fork(1, 2).unwrap();
    assert!(m.take_pending_copies().is_empty());
    assert_eq!(m.get(2).unwrap().written(), 4, "clamped to the shared boundary");
    assert_eq!(m.get(1).unwrap().written(), 6, "parent untouched");
    m.check_invariants();
}

#[test]
fn reserve_unshares_cow_page_with_exact_accounting() {
    // Page-aligned fork: every page is shared. A speculative reserve on
    // the child rewrites slot len-1, which lives in a shared page — that
    // page must be un-shared (copy-on-write) before the write.
    let mut m = KvCacheManager::new(16, 4, 8, true);
    m.set_page_copy(true);
    m.admit(1, &[1, 2, 3, 4, 5, 6, 7]).unwrap(); // 2 pages, 8 slots
    m.append_token(1, 8).unwrap(); // fills page 1
    m.note_written(1, 8);
    let t1 = m.get(1).unwrap().block_table.clone();
    m.fork(1, 2).unwrap();
    assert!(m.take_pending_copies().is_empty(), "aligned fork copies nothing");
    assert_eq!(m.get(2).unwrap().block_table, t1);
    assert_eq!(m.shared_pages(), 2);
    let avail = m.available_pages();

    m.reserve(2, 10).unwrap(); // verify window rewrites position 7
    let t2 = m.get(2).unwrap().block_table.clone();
    assert_eq!(t2[0], t1[0], "read-only page stays shared");
    assert_ne!(t2[1], t1[1], "rewritten page is un-shared");
    assert_eq!(t2.len(), 3);
    assert_eq!(m.allocator().refcount(t1[1]), 1, "parent owns its tail again");
    assert_eq!(m.available_pages(), avail - 2, "one CoW page + one growth page");
    assert_eq!(m.take_pending_copies(), vec![(t1[1], t2[1])]);
    // Branch A's divergence never reached branch B.
    assert_eq!(m.get(1).unwrap().block_table, t1);
    assert_eq!(m.get(1).unwrap().written(), 8);
    m.check_invariants();
    m.free(2);
    assert_eq!(m.allocator().refcount(t1[0]), 1);
    m.free(1);
    m.check_invariants();
}

#[test]
fn fork_rolls_back_on_exhaustion() {
    let mut m = KvCacheManager::new(5, 4, 8, false); // 4 usable pages
    m.set_page_copy(true);
    m.admit(1, &[0; 9]).unwrap(); // 3 pages
    m.note_written(1, 5); // page 0 full; pages 1-2 are unshareable tails
    assert_eq!(m.available_pages(), 1);
    let t1 = m.get(1).unwrap().block_table.clone();

    // The fork needs 2 fresh tail pages; the pool has 1.
    assert_eq!(m.fork(1, 2), Err(AllocError::OutOfPages));
    assert_eq!(m.num_sequences(), 1);
    assert_eq!(m.available_pages(), 1, "taken pages returned");
    assert!(m.take_pending_copies().is_empty(), "queued copies rolled back");
    for &p in &t1 {
        assert_eq!(m.allocator().refcount(p), 1, "parent refs unchanged");
    }
    m.check_invariants();
    m.free(1);
    m.check_invariants();
    assert_eq!(m.available_pages(), 4);
}

#[test]
fn family_frees_in_any_order_without_leaks_and_registers_prefix_once() {
    let mut m = KvCacheManager::new(16, 4, 8, true);
    m.set_page_copy(true);
    let prompt = [1u32, 2, 3, 4, 5, 6];
    let total = m.available_pages();
    for order in [[1u64, 2, 3], [3, 1, 2], [2, 3, 1]] {
        m.admit(1, &prompt).unwrap();
        m.note_written(1, 6);
        let shared_page = m.get(1).unwrap().block_table[0];
        m.fork(1, 2).unwrap();
        m.fork(1, 3).unwrap();
        let _ = m.take_pending_copies();
        assert_eq!(m.allocator().refcount(shared_page), 3);
        for (i, id) in order.iter().enumerate() {
            m.free(*id);
            m.check_invariants();
            let left = (order.len() - 1 - i) as u32;
            if left > 0 {
                assert_eq!(m.allocator().refcount(shared_page), left);
            }
        }
        assert_eq!(m.available_pages(), total, "family fully reclaimed");
        // The last-freeing sibling registered the shared full page: a
        // session turn re-admitting the same prefix hits it.
        let seq = m.admit(9, &prompt).unwrap();
        assert_eq!(seq.cached_tokens, 4, "shared page reused across turns");
        m.free(9);
        m.check_invariants();
    }
}

#[test]
fn dead_sequences_purge_their_pending_copies() {
    // A branch can be aborted between fork and the next backend call;
    // its queued tail copy must die with it, or the engine would later
    // copy into (or out of) a recycled page.
    let mut m = KvCacheManager::new(16, 4, 8, false);
    m.set_page_copy(true);
    m.admit(1, &[1, 2, 3, 4, 5, 6]).unwrap();
    m.note_written(1, 6);
    m.fork(1, 2).unwrap();
    m.free(2); // abort the branch, pending copy still queued
    assert!(m.take_pending_copies().is_empty(), "copy for a dead page purged");
    m.check_invariants();
    m.free(1);
    m.check_invariants();
}

#[test]
fn prop_random_fork_cow_keeps_invariants() {
    Runner::new("fork_cow_invariants", 120).run(|rng| {
        let ps = *rng.choose(&[4usize, 8]);
        let num_pages = 6 + rng.range(30);
        let mut m = KvCacheManager::new(num_pages, ps, 12, rng.bool());
        m.set_page_copy(rng.bool());
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..150 {
            match rng.range(6) {
                0 => {
                    let n = 1 + rng.range(ps * 3);
                    let toks: Vec<u32> = (0..n).map(|_| rng.range(64) as u32).collect();
                    next_id += 1;
                    if m.admit(next_id, &toks).is_ok() {
                        live.push(next_id);
                    }
                }
                1 if !live.is_empty() => {
                    // Fork a random live sequence (the n>1 fan-out shape).
                    let parent = *rng.choose(&live);
                    next_id += 1;
                    if m.fork(parent, next_id).is_ok() {
                        live.push(next_id);
                    }
                }
                2 if !live.is_empty() => {
                    let idx = rng.range(live.len());
                    let id = live.swap_remove(idx);
                    m.free(id);
                }
                3 if !live.is_empty() => {
                    let id = *rng.choose(&live);
                    let _ = m.append_token(id, rng.range(64) as u32);
                }
                4 if !live.is_empty() => {
                    // Speculative reserve: may trigger reserve-side CoW.
                    let id = *rng.choose(&live);
                    let len = m.get(id).unwrap().len();
                    let _ = m.reserve(id, len + rng.range(ps));
                }
                5 if !live.is_empty() => {
                    let id = *rng.choose(&live);
                    let len = m.get(id).unwrap().len();
                    m.note_written(id, rng.range(len + 1));
                }
                _ => {}
            }
            m.check_invariants();
            if rng.range(4) == 0 {
                // The engine drains copies before each backend call.
                let _ = m.take_pending_copies();
            }
        }
        for id in live {
            m.free(id);
        }
        m.check_invariants();
        if m.available_pages() != num_pages - 1 {
            return Err(format!(
                "leak: {} of {} pages available after freeing everything",
                m.available_pages(),
                num_pages - 1
            ));
        }
        Ok(())
    });
}

#[test]
fn invalidate_all_discards_sequences_pool_and_prefix_cache() {
    let mut m = KvCacheManager::new(16, 4, 8, true);
    let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8, 9];
    m.admit(1, &prompt).unwrap();
    m.note_written(1, prompt.len());
    m.free(1); // two full pages parked for reuse
    m.admit(2, &prompt).unwrap();
    assert_eq!(m.get(2).unwrap().cached_tokens, 8);

    // Device loss: everything — live seqs, free pages, parked prefix
    // pages — is garbage now.
    m.invalidate_all();
    m.check_invariants();
    assert_eq!(m.num_sequences(), 0);
    assert_eq!(m.available_pages(), 15); // pages 1..16; page 0 is garbage

    // Re-admitting the same prompt must NOT hit the (cleared) prefix
    // cache: a hit would read pages the lost device never rewrote.
    let seq = m.admit(3, &prompt).unwrap();
    assert_eq!(seq.cached_tokens, 0);
    let (hits, _) = m.prefix_stats();
    assert_eq!(hits, 0);
    m.check_invariants();
}
