use super::*;
use crate::json::{parse, to_string, Value};
use crate::tokenizer::Role;

#[test]
fn request_roundtrip_through_wire_format() {
    let req = ChatCompletionRequest::new("tiny-2m")
        .system("be terse")
        .user("hello");
    let mut req = req;
    req.max_tokens = 32;
    req.stream = true;
    req.stop = vec!["\n\n".into()];
    req.sampling.temperature = 0.5;
    req.sampling.seed = Some(7);
    req.response_format = ResponseFormat::JsonObject;
    req.deadline_ms = Some(1500);

    let wire = to_string(&req.to_json());
    let back = ChatCompletionRequest::from_json(&parse(&wire).unwrap()).unwrap();
    assert_eq!(back.model, "tiny-2m");
    assert_eq!(back.messages.len(), 2);
    assert_eq!(back.messages[0].role, Role::System);
    assert_eq!(back.max_tokens, 32);
    assert!(back.stream);
    assert_eq!(back.stop, vec!["\n\n".to_string()]);
    assert_eq!(back.sampling.temperature, 0.5);
    assert_eq!(back.sampling.seed, Some(7));
    assert_eq!(back.response_format, ResponseFormat::JsonObject);
    assert_eq!(back.deadline_ms, Some(1500));

    // Absent => None (engine default applies); negative is rejected.
    let plain = r#"{"model":"m","messages":[{"role":"user","content":"x"}]}"#;
    let req = ChatCompletionRequest::from_json(&parse(plain).unwrap()).unwrap();
    assert_eq!(req.deadline_ms, None);
    let bad = r#"{"model":"m","messages":[{"role":"user","content":"x"}],"deadline_ms":-5}"#;
    let err = ChatCompletionRequest::from_json(&parse(bad).unwrap()).unwrap_err();
    assert!(err.message.contains("deadline_ms"), "{err}");
}

#[test]
fn request_validation_errors() {
    for (body, needle) in [
        (r#"{}"#, "model"),
        (r#"{"model":"m"}"#, "messages"),
        (r#"{"model":"m","messages":[]}"#, "non-empty"),
        (r#"{"model":"m","messages":[{"role":"wizard","content":"x"}]}"#, "role"),
        (r#"{"model":"m","messages":[{"role":"user"}]}"#, "content"),
        (r#"{"model":"m","messages":[{"role":"user","content":"x"}],"temperature":9}"#, "temperature"),
        (r#"{"model":"m","messages":[{"role":"user","content":"x"}],"max_tokens":0}"#, "max_tokens"),
        (r#"{"model":"m","messages":[{"role":"user","content":"x"}],"stop":["a","b","c","d","e"]}"#, "stop"),
        (r#"{"model":"m","messages":[{"role":"user","content":"x"}],"logit_bias":{"abc":1}}"#, "logit_bias"),
        (r#"{"model":"m","messages":[{"role":"user","content":"x"}],"response_format":{"type":"yaml"}}"#, "response_format"),
    ] {
        let err = ChatCompletionRequest::from_json(&parse(body).unwrap()).unwrap_err();
        assert_eq!(err.status, 400, "{body}");
        assert!(err.message.contains(needle), "{body}: {err}");
    }
}

#[test]
fn request_json_schema_format() {
    let body = r#"{
        "model": "m",
        "messages": [{"role": "user", "content": "x"}],
        "response_format": {
            "type": "json_schema",
            "json_schema": {"name": "out", "schema": {"type": "object"}}
        }
    }"#;
    let req = ChatCompletionRequest::from_json(&parse(body).unwrap()).unwrap();
    match req.response_format {
        ResponseFormat::JsonSchema(s) => {
            assert_eq!(s.get("type").unwrap().as_str(), Some("object"));
        }
        other => panic!("wrong format {other:?}"),
    }
}

#[test]
fn response_roundtrip() {
    let resp = ChatCompletionResponse {
        id: "chatcmpl-1".into(),
        model: "tiny-2m".into(),
        created: 1736500000,
        choices: vec![Choice {
            index: 0,
            content: "hi there".into(),
            finish_reason: FinishReason::Stop,
            logprobs: Some(vec![LogprobEntry {
                token: "hi".into(),
                logprob: -0.25,
                top: vec![("hi".into(), -0.25), ("yo".into(), -1.5)],
            }]),
        }],
        usage: Usage {
            prompt_tokens: 12,
            completion_tokens: 3,
            prefill_tokens_per_s: 100.0,
            decode_tokens_per_s: 40.0,
            ttft_s: 0.2,
            e2e_s: 0.3,
        },
    };
    let wire = to_string(&resp.to_json());
    let v = parse(&wire).unwrap();
    assert_eq!(v.get("object").unwrap().as_str(), Some("chat.completion"));
    assert_eq!(
        v.get("usage").unwrap().get("total_tokens").unwrap().as_usize(),
        Some(15)
    );
    let back = ChatCompletionResponse::from_json(&v).unwrap();
    assert_eq!(back.text(), "hi there");
    assert_eq!(back.usage.completion_tokens, 3);
    assert!((back.usage.decode_tokens_per_s - 40.0).abs() < 1e-9);
    let lps = back.choices[0].logprobs.as_ref().unwrap();
    assert_eq!(lps.len(), 1);
    assert_eq!(lps[0].token, "hi");
    assert_eq!(lps[0].top.len(), 2);
}

#[test]
fn chunk_roundtrip_and_final_chunk() {
    let mid = ChatChunk {
        id: "c1".into(),
        model: "m".into(),
        index: 1,
        delta: "tok".into(),
        finish_reason: None,
        usage: None,
    };
    let v = mid.to_json();
    assert_eq!(v.get("object").unwrap().as_str(), Some("chat.completion.chunk"));
    assert_eq!(ChatChunk::from_json(&v).unwrap(), mid);

    let last = ChatChunk {
        id: "c1".into(),
        model: "m".into(),
        index: 2,
        delta: "".into(),
        finish_reason: Some(FinishReason::Length),
        usage: Some(Usage { prompt_tokens: 1, completion_tokens: 2, ..Default::default() }),
    };
    let back = ChatChunk::from_json(&last.to_json()).unwrap();
    assert_eq!(back.finish_reason, Some(FinishReason::Length));
    assert_eq!(back.usage.as_ref().unwrap().completion_tokens, 2);
}

#[test]
fn logprobs_roundtrip_and_request_validation() {
    // request parse
    let body = r#"{"model":"m","messages":[{"role":"user","content":"x"}],
                   "logprobs":true,"top_logprobs":3}"#;
    let req = ChatCompletionRequest::from_json(&parse(body).unwrap()).unwrap();
    assert!(req.sampling.logprobs);
    assert_eq!(req.sampling.top_logprobs, 3);
    // top_logprobs without logprobs -> 400
    let bad = r#"{"model":"m","messages":[{"role":"user","content":"x"}],"top_logprobs":3}"#;
    assert!(ChatCompletionRequest::from_json(&parse(bad).unwrap()).is_err());
    // out-of-range
    let bad = r#"{"model":"m","messages":[{"role":"user","content":"x"}],
                  "logprobs":true,"top_logprobs":99}"#;
    assert!(ChatCompletionRequest::from_json(&parse(bad).unwrap()).is_err());
}

#[test]
fn api_error_shape() {
    let e = ApiError::invalid("bad thing");
    let v = e.to_json();
    assert_eq!(v.get("error").unwrap().get("code").unwrap().as_u64(), Some(400));
    let back = ApiError::from_json(&v).unwrap();
    assert_eq!(back, e);
    assert_eq!(ApiError::from_json(&Value::Null), None);
}
