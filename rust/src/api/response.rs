//! Chat-completion responses, streaming chunks, usage accounting.

use crate::json::Value;

/// Per-token logprob entry in a choice (OpenAI `logprobs.content[i]`).
#[derive(Clone, Debug, PartialEq)]
pub struct LogprobEntry {
    pub token: String,
    pub logprob: f64,
    pub top: Vec<(String, f64)>,
}

impl LogprobEntry {
    fn to_json(&self) -> Value {
        let top: Vec<Value> = self
            .top
            .iter()
            .map(|(t, lp)| crate::obj! {"token" => t.clone(), "logprob" => *lp})
            .collect();
        crate::obj! {
            "token" => self.token.clone(),
            "logprob" => self.logprob,
            "top_logprobs" => Value::Array(top),
        }
    }

    fn from_json(v: &Value) -> Option<Self> {
        Some(Self {
            token: v.get("token")?.as_str()?.to_string(),
            logprob: v.get("logprob")?.as_f64()?,
            top: v
                .get("top_logprobs")?
                .as_array()?
                .iter()
                .filter_map(|t| {
                    Some((t.get("token")?.as_str()?.to_string(), t.get("logprob")?.as_f64()?))
                })
                .collect(),
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Stop,
    Length,
    Abort,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::Abort => "abort",
        }
    }

    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "stop" => Some(FinishReason::Stop),
            "length" => Some(FinishReason::Length),
            "abort" => Some(FinishReason::Abort),
            _ => None,
        }
    }
}

/// Token + timing accounting; the `extra` fields mirror WebLLM's
/// `CompletionUsage.extra` (prefill/decode tokens-per-second).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Usage {
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    pub prefill_tokens_per_s: f64,
    pub decode_tokens_per_s: f64,
    /// Seconds from admission to first token (time-to-first-token).
    pub ttft_s: f64,
    /// End-to-end seconds.
    pub e2e_s: f64,
}

impl Usage {
    pub fn to_json(&self) -> Value {
        crate::obj! {
            "prompt_tokens" => self.prompt_tokens,
            "completion_tokens" => self.completion_tokens,
            "total_tokens" => self.prompt_tokens + self.completion_tokens,
            "extra" => crate::obj! {
                "prefill_tokens_per_s" => self.prefill_tokens_per_s,
                "decode_tokens_per_s" => self.decode_tokens_per_s,
                "ttft_s" => self.ttft_s,
                "e2e_s" => self.e2e_s,
            },
        }
    }

    pub fn from_json(v: &Value) -> Option<Self> {
        let extra = v.get("extra");
        let g = |k: &str| extra.and_then(|e| e.get(k)).and_then(Value::as_f64).unwrap_or(0.0);
        Some(Self {
            prompt_tokens: v.get("prompt_tokens")?.as_usize()?,
            completion_tokens: v.get("completion_tokens")?.as_usize()?,
            prefill_tokens_per_s: g("prefill_tokens_per_s"),
            decode_tokens_per_s: g("decode_tokens_per_s"),
            ttft_s: g("ttft_s"),
            e2e_s: g("e2e_s"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct Choice {
    pub index: usize,
    pub content: String,
    pub finish_reason: FinishReason,
    /// Present when the request set `logprobs: true`.
    pub logprobs: Option<Vec<LogprobEntry>>,
}

#[derive(Clone, Debug)]
pub struct ChatCompletionResponse {
    pub id: String,
    pub model: String,
    pub created: u64,
    pub choices: Vec<Choice>,
    pub usage: Usage,
}

impl ChatCompletionResponse {
    pub fn text(&self) -> &str {
        self.choices.first().map(|c| c.content.as_str()).unwrap_or("")
    }

    pub fn to_json(&self) -> Value {
        let choices: Vec<Value> = self
            .choices
            .iter()
            .map(|c| {
                let mut v = crate::obj! {
                    "index" => c.index,
                    "message" => crate::obj! {
                        "role" => "assistant",
                        "content" => c.content.clone(),
                    },
                    "finish_reason" => c.finish_reason.as_str(),
                };
                if let Some(lps) = &c.logprobs {
                    let content: Vec<Value> = lps.iter().map(LogprobEntry::to_json).collect();
                    v.set("logprobs", crate::obj! {"content" => Value::Array(content)});
                }
                v
            })
            .collect();
        crate::obj! {
            "id" => self.id.clone(),
            "object" => "chat.completion",
            "created" => self.created as i64,
            "model" => self.model.clone(),
            "choices" => Value::Array(choices),
            "usage" => self.usage.to_json(),
        }
    }

    pub fn from_json(v: &Value) -> Option<Self> {
        let choices = v
            .get("choices")?
            .as_array()?
            .iter()
            .map(|c| {
                let logprobs = c
                    .get("logprobs")
                    .and_then(|l| l.get("content"))
                    .and_then(Value::as_array)
                    .map(|a| a.iter().filter_map(LogprobEntry::from_json).collect());
                Some(Choice {
                    index: c.get("index")?.as_usize()?,
                    content: c.get("message")?.get("content")?.as_str()?.to_string(),
                    finish_reason: FinishReason::from_str(
                        c.get("finish_reason")?.as_str()?,
                    )?,
                    logprobs,
                })
            })
            .collect::<Option<Vec<_>>>()?;
        Some(Self {
            id: v.get("id")?.as_str()?.to_string(),
            model: v.get("model")?.as_str()?.to_string(),
            created: v.get("created")?.as_u64()?,
            choices,
            usage: Usage::from_json(v.get("usage")?)?,
        })
    }
}

/// One streaming delta (`object: chat.completion.chunk`).
#[derive(Clone, Debug, PartialEq)]
pub struct ChatChunk {
    pub id: String,
    pub model: String,
    /// Which choice this delta extends (`n>1` requests interleave the
    /// chunks of all their branches on one stream; 0 for `n=1`).
    pub index: usize,
    pub delta: String,
    /// Set on the final chunk of this choice.
    pub finish_reason: Option<FinishReason>,
    /// Usage rides on the final chunk (stream_options include_usage
    /// style); for `n>1` it is the whole request's aggregate, carried by
    /// the last choice to finish.
    pub usage: Option<Usage>,
}

impl ChatChunk {
    pub fn to_json(&self) -> Value {
        let mut delta = Value::object();
        if !self.delta.is_empty() {
            delta.set("content", self.delta.clone());
        }
        let choice = crate::obj! {
            "index" => self.index,
            "delta" => delta,
            "finish_reason" => match self.finish_reason {
                Some(fr) => Value::from(fr.as_str()),
                None => Value::Null,
            },
        };
        let mut v = crate::obj! {
            "id" => self.id.clone(),
            "object" => "chat.completion.chunk",
            "model" => self.model.clone(),
            "choices" => Value::Array(vec![choice]),
        };
        if let Some(u) = &self.usage {
            v.set("usage", u.to_json());
        }
        v
    }

    pub fn from_json(v: &Value) -> Option<Self> {
        let c0 = v.get("choices")?.at(0)?;
        Some(Self {
            id: v.get("id")?.as_str()?.to_string(),
            model: v.get("model")?.as_str()?.to_string(),
            index: c0.get("index").and_then(Value::as_usize).unwrap_or(0),
            delta: c0
                .get("delta")
                .and_then(|d| d.get("content"))
                .and_then(Value::as_str)
                .unwrap_or("")
                .to_string(),
            finish_reason: c0
                .get("finish_reason")
                .and_then(Value::as_str)
                .and_then(FinishReason::from_str),
            usage: v.get("usage").and_then(Usage::from_json),
        })
    }
}
