//! OpenAI-style API types (the paper's §2.1 contract).
//!
//! JSON-in-JSON-out: every type (de)serializes through `crate::json` and
//! is exactly what crosses the worker message boundary and the HTTP
//! endpoint. Field names and semantics follow the OpenAI chat-completions
//! API, plus the WebLLM extensions (`response_format: grammar`, `top_k`,
//! `min_p`, `repetition_penalty`).

mod request;
mod response;

pub use request::{ChatCompletionRequest, ResponseFormat};
pub use response::{ChatChunk, ChatCompletionResponse, Choice, FinishReason, LogprobEntry, Usage};

use crate::json::Value;

/// API-level error with an HTTP-ish status code, serialized OpenAI-style:
/// `{"error": {"message": ..., "type": ..., "code": ...}}`.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub status: u16,
    pub kind: String,
    pub message: String,
}

impl ApiError {
    pub fn invalid(message: impl Into<String>) -> Self {
        Self { status: 400, kind: "invalid_request_error".into(), message: message.into() }
    }

    pub fn not_found(message: impl Into<String>) -> Self {
        Self { status: 404, kind: "not_found_error".into(), message: message.into() }
    }

    pub fn overloaded(message: impl Into<String>) -> Self {
        Self { status: 429, kind: "overloaded_error".into(), message: message.into() }
    }

    /// Admission back-pressure: the target model's waiting queue is at
    /// capacity, so `submit` rejects instead of queueing unboundedly.
    /// The HTTP layer maps any 429 to a `Retry-After` header; clients
    /// should back off and resubmit (possibly at a higher `priority`).
    pub fn queue_full(message: impl Into<String>) -> Self {
        Self { status: 429, kind: "queue_full".into(), message: message.into() }
    }

    pub fn internal(message: impl Into<String>) -> Self {
        Self { status: 500, kind: "internal_error".into(), message: message.into() }
    }

    /// A request exceeded its deadline (`deadline_ms` /
    /// `--request-timeout`) or an engine channel wait timed out
    /// (`--engine-timeout`). Structured so clients can tell a timeout
    /// from a genuine internal failure.
    pub fn timeout(message: impl Into<String>) -> Self {
        Self { status: 408, kind: "timeout_error".into(), message: message.into() }
    }

    /// The engine is draining (graceful shutdown): no new admissions.
    /// The HTTP layer adds `Retry-After` so clients resubmit elsewhere.
    pub fn unavailable(message: impl Into<String>) -> Self {
        Self { status: 503, kind: "draining".into(), message: message.into() }
    }

    /// A data-plane fault (e.g. a non-finite logits row) failed exactly
    /// this request; the engine itself kept running.
    pub fn data_plane(message: impl Into<String>) -> Self {
        Self { status: 500, kind: "data_plane_error".into(), message: message.into() }
    }

    pub fn to_json(&self) -> Value {
        crate::obj! {
            "error" => crate::obj! {
                "message" => self.message.clone(),
                "type" => self.kind.clone(),
                "code" => self.status as i64,
            }
        }
    }

    pub fn from_json(v: &Value) -> Option<Self> {
        let e = v.get("error")?;
        Some(Self {
            status: e.get("code")?.as_u64()? as u16,
            kind: e.get("type")?.as_str()?.to_string(),
            message: e.get("message")?.as_str()?.to_string(),
        })
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} ({}): {}", self.kind, self.status, self.message)
    }
}

impl std::error::Error for ApiError {}

#[cfg(test)]
mod tests;
