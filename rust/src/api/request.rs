//! Chat-completion request parsing + validation.

use super::ApiError;
use crate::json::Value;
use crate::sampler::SamplingParams;
use crate::tokenizer::{ChatMessage, Role};
use std::collections::HashMap;

/// `response_format` — structured generation controls (WebLLM supports
/// JSON mode, JSON Schema, and raw EBNF grammars via XGrammar).
#[derive(Clone, Debug, PartialEq)]
pub enum ResponseFormat {
    Text,
    /// Any syntactically valid JSON value.
    JsonObject,
    /// JSON constrained by a schema.
    JsonSchema(Value),
    /// GBNF-style grammar text.
    Grammar(String),
}

#[derive(Clone, Debug)]
pub struct ChatCompletionRequest {
    pub model: String,
    pub messages: Vec<ChatMessage>,
    pub max_tokens: usize,
    pub stream: bool,
    pub stop: Vec<String>,
    pub sampling: SamplingParams,
    pub response_format: ResponseFormat,
    /// Scheduling class (WebLLM extension): higher values are admitted
    /// first, receive prefill chunks first, and are the last preempted
    /// under memory pressure. Ties break by arrival order. Default 0.
    pub priority: i32,
    /// Per-request deadline in milliseconds from submission (WebLLM
    /// extension): past it the scheduler fails the request with a
    /// structured `timeout_error` instead of running it to completion.
    /// `None` falls back to the engine's `--request-timeout` default.
    pub deadline_ms: Option<u64>,
    /// Number of parallel completions (OpenAI `n`). The engine prefills
    /// the prompt once, forks the KV pages, and decodes `n` branches
    /// with independent sampler state; choices stream with their own
    /// `index` and the final response carries all `n`. Default 1.
    pub n: usize,
}

impl ChatCompletionRequest {
    pub fn new(model: impl Into<String>) -> Self {
        Self {
            model: model.into(),
            messages: Vec::new(),
            max_tokens: 128,
            stream: false,
            stop: Vec::new(),
            sampling: SamplingParams::default(),
            response_format: ResponseFormat::Text,
            priority: 0,
            deadline_ms: None,
            n: 1,
        }
    }

    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_n(mut self, n: usize) -> Self {
        self.n = n;
        self
    }

    pub fn with_deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    pub fn message(mut self, role: Role, content: impl Into<String>) -> Self {
        self.messages.push(ChatMessage::new(role, content));
        self
    }

    pub fn system(self, content: impl Into<String>) -> Self {
        self.message(Role::System, content)
    }

    pub fn user(self, content: impl Into<String>) -> Self {
        self.message(Role::User, content)
    }

    pub fn from_json(v: &Value) -> Result<Self, ApiError> {
        let model = v
            .get("model")
            .and_then(Value::as_str)
            .ok_or_else(|| ApiError::invalid("'model' is required"))?
            .to_string();
        let messages_v = v
            .get("messages")
            .and_then(Value::as_array)
            .ok_or_else(|| ApiError::invalid("'messages' is required"))?;
        if messages_v.is_empty() {
            return Err(ApiError::invalid("'messages' must be non-empty"));
        }
        let mut messages = Vec::with_capacity(messages_v.len());
        for m in messages_v {
            let role_s = m
                .get("role")
                .and_then(Value::as_str)
                .ok_or_else(|| ApiError::invalid("message missing 'role'"))?;
            let role = Role::from_str(role_s)
                .ok_or_else(|| ApiError::invalid(format!("unsupported role '{role_s}'")))?;
            let content = m
                .get("content")
                .and_then(Value::as_str)
                .ok_or_else(|| ApiError::invalid("message missing 'content'"))?;
            messages.push(ChatMessage::new(role, content));
        }

        let f = |k: &str, d: f32| -> Result<f32, ApiError> {
            match v.get(k) {
                None | Some(Value::Null) => Ok(d),
                Some(x) => x
                    .as_f64()
                    .map(|x| x as f32)
                    .ok_or_else(|| ApiError::invalid(format!("'{k}' must be a number"))),
            }
        };

        let mut logit_bias = HashMap::new();
        if let Some(lb) = v.get("logit_bias").and_then(Value::as_object) {
            for (k, bias) in lb.iter() {
                let tok: u32 = k
                    .parse()
                    .map_err(|_| ApiError::invalid(format!("logit_bias key '{k}' not a token id")))?;
                let b = bias
                    .as_f64()
                    .ok_or_else(|| ApiError::invalid("logit_bias values must be numbers"))?;
                logit_bias.insert(tok, b as f32);
            }
        }

        let logprobs = v.get("logprobs").and_then(Value::as_bool).unwrap_or(false);
        let top_logprobs = v.get("top_logprobs").and_then(Value::as_usize).unwrap_or(0);
        if top_logprobs > 0 && !logprobs {
            return Err(ApiError::invalid("'top_logprobs' requires 'logprobs': true"));
        }
        let sampling = SamplingParams {
            temperature: f("temperature", 1.0)?,
            top_p: f("top_p", 1.0)?,
            top_k: v.get("top_k").and_then(Value::as_usize).unwrap_or(0),
            min_p: f("min_p", 0.0)?,
            repetition_penalty: f("repetition_penalty", 1.0)?,
            presence_penalty: f("presence_penalty", 0.0)?,
            frequency_penalty: f("frequency_penalty", 0.0)?,
            logit_bias,
            seed: v.get("seed").and_then(Value::as_u64),
            logprobs,
            top_logprobs,
        };
        sampling.validate().map_err(ApiError::invalid)?;

        let stop = match v.get("stop") {
            None | Some(Value::Null) => Vec::new(),
            Some(Value::String(s)) => vec![s.clone()],
            Some(Value::Array(a)) => a
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(String::from)
                        .ok_or_else(|| ApiError::invalid("'stop' entries must be strings"))
                })
                .collect::<Result<_, _>>()?,
            _ => return Err(ApiError::invalid("'stop' must be a string or array")),
        };
        if stop.len() > 4 {
            return Err(ApiError::invalid("at most 4 stop sequences"));
        }

        let response_format = match v.get("response_format") {
            None | Some(Value::Null) => ResponseFormat::Text,
            Some(rf) => {
                let ty = rf
                    .get("type")
                    .and_then(Value::as_str)
                    .ok_or_else(|| ApiError::invalid("response_format missing 'type'"))?;
                match ty {
                    "text" => ResponseFormat::Text,
                    "json_object" => ResponseFormat::JsonObject,
                    "json_schema" => {
                        let schema = rf
                            .get("json_schema")
                            .and_then(|s| s.get("schema"))
                            .or_else(|| rf.get("schema"))
                            .ok_or_else(|| ApiError::invalid("json_schema needs a 'schema'"))?;
                        ResponseFormat::JsonSchema(schema.clone())
                    }
                    "grammar" => {
                        let g = rf
                            .get("grammar")
                            .and_then(Value::as_str)
                            .ok_or_else(|| ApiError::invalid("grammar format needs 'grammar'"))?;
                        ResponseFormat::Grammar(g.to_string())
                    }
                    other => {
                        return Err(ApiError::invalid(format!(
                            "unsupported response_format type '{other}'"
                        )))
                    }
                }
            }
        };

        let max_tokens = match v.get("max_tokens") {
            None | Some(Value::Null) => 128,
            Some(x) => {
                let n = x.as_usize().ok_or_else(|| ApiError::invalid("'max_tokens' must be a positive integer"))?;
                if n == 0 {
                    return Err(ApiError::invalid("'max_tokens' must be >= 1"));
                }
                n
            }
        };

        let priority = match v.get("priority") {
            None | Some(Value::Null) => 0,
            Some(x) => x
                .as_i64()
                .and_then(|n| i32::try_from(n).ok())
                .ok_or_else(|| ApiError::invalid("'priority' must be an integer"))?,
        };

        let deadline_ms = match v.get("deadline_ms") {
            None | Some(Value::Null) => None,
            Some(x) => Some(
                x.as_i64()
                    .and_then(|n| u64::try_from(n).ok())
                    .ok_or_else(|| ApiError::invalid("'deadline_ms' must be a non-negative integer"))?,
            ),
        };

        let n = match v.get("n") {
            None | Some(Value::Null) => 1,
            Some(x) => {
                let n = x
                    .as_usize()
                    .ok_or_else(|| ApiError::invalid("'n' must be a positive integer"))?;
                if n == 0 {
                    return Err(ApiError::invalid("'n' must be >= 1"));
                }
                n
            }
        };

        Ok(Self {
            model,
            messages,
            max_tokens,
            stream: v.get("stream").and_then(Value::as_bool).unwrap_or(false),
            stop,
            sampling,
            response_format,
            priority,
            deadline_ms,
            n,
        })
    }

    pub fn to_json(&self) -> Value {
        let mut msgs = Vec::new();
        for m in &self.messages {
            msgs.push(crate::obj! {
                "role" => m.role.as_str(),
                "content" => m.content.clone(),
            });
        }
        let mut v = crate::obj! {
            "model" => self.model.clone(),
            "messages" => Value::Array(msgs),
            "max_tokens" => self.max_tokens,
            "stream" => self.stream,
            "temperature" => self.sampling.temperature as f64,
            "top_p" => self.sampling.top_p as f64,
        };
        if self.sampling.top_k > 0 {
            v.set("top_k", self.sampling.top_k);
        }
        if self.sampling.min_p > 0.0 {
            v.set("min_p", self.sampling.min_p as f64);
        }
        if self.sampling.repetition_penalty != 1.0 {
            v.set("repetition_penalty", self.sampling.repetition_penalty as f64);
        }
        if self.sampling.presence_penalty != 0.0 {
            v.set("presence_penalty", self.sampling.presence_penalty as f64);
        }
        if self.sampling.frequency_penalty != 0.0 {
            v.set("frequency_penalty", self.sampling.frequency_penalty as f64);
        }
        if let Some(seed) = self.sampling.seed {
            v.set("seed", seed as i64);
        }
        if self.sampling.logprobs {
            v.set("logprobs", true);
            if self.sampling.top_logprobs > 0 {
                v.set("top_logprobs", self.sampling.top_logprobs);
            }
        }
        if !self.sampling.logit_bias.is_empty() {
            let mut lb = crate::json::Map::new();
            for (&t, &b) in &self.sampling.logit_bias {
                lb.insert(t.to_string(), b as f64);
            }
            v.set("logit_bias", lb);
        }
        if !self.stop.is_empty() {
            v.set("stop", self.stop.clone());
        }
        if self.priority != 0 {
            v.set("priority", self.priority as i64);
        }
        if let Some(ms) = self.deadline_ms {
            v.set("deadline_ms", ms as i64);
        }
        if self.n != 1 {
            v.set("n", self.n);
        }
        match &self.response_format {
            ResponseFormat::Text => {}
            ResponseFormat::JsonObject => {
                v.set("response_format", crate::obj! {"type" => "json_object"});
            }
            ResponseFormat::JsonSchema(s) => {
                v.set(
                    "response_format",
                    crate::obj! {"type" => "json_schema", "schema" => s.clone()},
                );
            }
            ResponseFormat::Grammar(g) => {
                v.set(
                    "response_format",
                    crate::obj! {"type" => "grammar", "grammar" => g.clone()},
                );
            }
        }
        v
    }
}
