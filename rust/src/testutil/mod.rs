//! Test utilities: a minimal property-testing harness and the shared
//! backend-conformance suite.
//!
//! The vendored crate set has no proptest/quickcheck, so invariant tests
//! (scheduler, kv-cache, grammar, json) use this seeded-PRNG runner. It
//! reports the failing iteration's seed so a failure reproduces with
//! `WEBLLM_PROP_SEED=<seed> cargo test <name>`.
//!
//! `backend_contract` holds the [`crate::runtime::ModelBackend`]
//! contract as executable assertions, run against the reference backend
//! unconditionally and against compiled XLA artifacts when present.

pub mod backend_contract;
pub mod prop;
pub mod schema_oracle;

use crate::api::ChatCompletionRequest;

/// Ban the reference tokenizer's EOS specials (`<eos>` = 2, `<|end|>` =
/// 7) so a greedy run generates exactly `max_tokens` tokens — for tests
/// and benches that need a deterministic token count.
pub fn ban_reference_eos(r: &mut ChatCompletionRequest) {
    for id in [2u32, 7] {
        r.sampling.logit_bias.insert(id, -100.0);
    }
}

/// Additionally ban every empty-byte token of the reference vocabulary
/// (specials 0..8, unused tail 268..300) so each generated token
/// contributes visible text — for streaming tests that count deltas.
pub fn ban_reference_invisible(r: &mut ChatCompletionRequest) {
    ban_reference_eos(r);
    for id in (0..8u32).chain(268..300) {
        r.sampling.logit_bias.insert(id, -100.0);
    }
}
