//! Test utilities: a minimal property-testing harness.
//!
//! The vendored crate set has no proptest/quickcheck, so invariant tests
//! (scheduler, kv-cache, grammar, json) use this seeded-PRNG runner. It
//! reports the failing iteration's seed so a failure reproduces with
//! `WEBLLM_PROP_SEED=<seed> cargo test <name>`.

pub mod prop;
