//! Backend-conformance suite: the [`ModelBackend`] contract as
//! executable assertions, parameterized over any implementation.
//!
//! `tests/test_runtime.rs` (XLA, artifact-gated) and
//! `tests/test_reference_backend.rs` (reference, always) used to assert
//! the same contract by hand; this module is the single source of truth
//! both suites run, so the two backends can't drift. The reference
//! backend runs it with exact equality (`tol == 0.0`); the XLA backend
//! with a small float tolerance (kernel reassociation).
//!
//! Checks that are *stricter* than the shared contract — the reference
//! backend's hard error on reading unwritten KV slots, its all-zero
//! padding rows — stay in the reference suite: the XLA executables
//! produce well-defined-but-unspecified values there instead of
//! failing.

use crate::runtime::ModelBackend;

/// Pad `ids` with zeros to `chunk` slots (the compiled static shape).
pub fn padded(ids: &[i32], chunk: usize) -> Vec<i32> {
    assert!(ids.len() <= chunk, "{} tokens > chunk {chunk}", ids.len());
    let mut v = vec![0i32; chunk];
    v[..ids.len()].copy_from_slice(ids);
    v
}

/// The conformance runner: a factory for fresh backend instances (several
/// checks need two instances with identical state) plus the logit
/// comparison tolerance.
pub struct BackendConformance {
    make: Box<dyn Fn() -> Box<dyn ModelBackend>>,
    tol: f32,
}

impl BackendConformance {
    /// Exact-equality conformance (deterministic backends).
    pub fn new(make: impl Fn() -> Box<dyn ModelBackend> + 'static) -> Self {
        Self { make: Box::new(make), tol: 0.0 }
    }

    /// Allow `tol` max absolute logit difference where the contract says
    /// "equal" (floating-point backends).
    pub fn with_tolerance(mut self, tol: f32) -> Self {
        self.tol = tol;
        self
    }

    fn fresh(&self) -> Box<dyn ModelBackend> {
        (self.make)()
    }

    fn assert_close(&self, a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: logit length mismatch");
        if self.tol == 0.0 {
            assert_eq!(a, b, "{what}: logits differ (exact contract)");
        } else {
            let max = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(max <= self.tol, "{what}: max |delta| {max} > tol {}", self.tol);
        }
    }

    fn assert_far(a: &[f32], b: &[f32], what: &str) {
        let max = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max > 1e-6, "{what}: logits did not change");
    }

    /// Decode one live sequence through the smallest compiled batch,
    /// padding the remaining slots; returns the live row's logits.
    fn decode_single(
        rt: &mut dyn ModelBackend,
        token: i32,
        pos: i32,
        len: i32,
        bt: &[i32],
    ) -> Vec<f32> {
        let mc = rt.config().clone();
        let b = mc.pick_batch(1).expect("decode menu is non-empty");
        let mp = mc.max_pages_per_seq();
        let mut ids = vec![0i32; b];
        ids[0] = token;
        let mut positions = vec![0i32; b];
        positions[0] = pos;
        let mut lens = vec![0i32; b];
        lens[0] = len;
        let mut tables = vec![0i32; b * mp];
        tables[..mp].copy_from_slice(bt);
        let out = rt.decode(&ids, &positions, &lens, &tables).expect("decode");
        out.logits[..mc.vocab_size].to_vec()
    }

    /// Every check, in order. Each is also callable individually for
    /// finer-grained test names.
    pub fn run_all(&self) {
        self.reports_compiled_shapes();
        self.shape_errors_are_reported();
        self.kv_cache_chains_across_steps();
        self.reset_cache_restores_initial_state();
        self.batch_menu_is_transparent();
        self.logits_address_page_contents_not_page_ids();
        self.chunked_prefill_matches_whole_prompt();
        self.chunked_prefill_reads_resident_prefix_pages();
        self.verify_chunk_matches_sequential_decode();
        self.recompute_after_reset_matches_uninterrupted_chain();
        self.forked_family_decodes_like_independent_sequences();
    }

    /// Menus are non-empty, ascending, and sized within the model config.
    pub fn reports_compiled_shapes(&self) {
        let rt = self.fresh();
        let mc = rt.config().clone();
        let chunks = rt.compiled_chunks();
        let batches = rt.compiled_batches();
        assert!(!chunks.is_empty() && !batches.is_empty());
        assert!(chunks.windows(2).all(|w| w[0] < w[1]), "chunks not ascending");
        assert!(batches.windows(2).all(|w| w[0] < w[1]), "batches not ascending");
        assert!(*chunks.last().unwrap() <= mc.max_seq_len);
        assert!(rt.load_seconds() >= 0.0);
        assert!(rt.weight_bytes() > 0);
    }

    /// Malformed static shapes are rejected, not silently reinterpreted.
    pub fn shape_errors_are_reported(&self) {
        let mut rt = self.fresh();
        let mc = rt.config().clone();
        let mp = mc.max_pages_per_seq();
        let c0 = rt.compiled_chunks()[0];
        let bad_chunk = c0 + 1;
        if !rt.compiled_chunks().contains(&bad_chunk) {
            assert!(
                rt.prefill(&vec![0; bad_chunk], 1, &vec![0; mp]).is_err(),
                "uncompiled chunk size accepted"
            );
        }
        // wrong block-table length
        assert!(rt.prefill(&vec![0; c0], 1, &vec![0; mp + 1]).is_err());
        // zero valid tokens
        assert!(rt.prefill(&vec![0; c0], 0, &vec![0; mp]).is_err());
        // more valid tokens than the chunk holds
        assert!(rt.prefill(&vec![0; c0], c0 + 1, &vec![0; mp]).is_err());
        // chunk reaching past the block table
        assert!(
            rt.prefill_chunk(&vec![0; c0], mp * mc.page_size, 1, &vec![1; mp]).is_err(),
            "chunk past the table's reach accepted"
        );
        // uncompiled batch size
        let bad_batch = rt.compiled_batches().last().unwrap() + 1;
        assert!(rt
            .decode(
                &vec![0; bad_batch],
                &vec![0; bad_batch],
                &vec![0; bad_batch],
                &vec![0; bad_batch * mp],
            )
            .is_err());
        // inconsistent decode input lengths
        let b0 = rt.compiled_batches()[0];
        assert!(rt
            .decode(&vec![0; b0], &vec![0; b0 + 1], &vec![0; b0], &vec![0; b0 * mp])
            .is_err());
    }

    /// Decoding the same token at successive positions must change the
    /// logits: the KV state actually chains between steps.
    pub fn kv_cache_chains_across_steps(&self) {
        let mut rt = self.fresh();
        let mc = rt.config().clone();
        let chunk = rt.compiled_chunks()[0];
        let mut bt = vec![0i32; mc.max_pages_per_seq()];
        bt[0] = 1;
        bt[1] = 2;
        let out = rt.prefill(&padded(&[10, 11, 12, 13], chunk), 4, &bt).expect("prefill");
        assert_eq!(out.logits.len(), mc.vocab_size);
        let one = Self::decode_single(rt.as_mut(), 42, 4, 5, &bt);
        let two = Self::decode_single(rt.as_mut(), 42, 5, 6, &bt);
        Self::assert_far(&one, &two, "same token, longer prefix");
    }

    /// `reset_cache` restores the pristine pool: a replayed prefill sees
    /// exactly the first run's logits.
    pub fn reset_cache_restores_initial_state(&self) {
        let mut rt = self.fresh();
        let mc = rt.config().clone();
        let chunk = rt.compiled_chunks()[0];
        let mut bt = vec![0i32; mc.max_pages_per_seq()];
        bt[0] = 1;
        let ids = padded(&[7, 8, 9], chunk);
        let a = rt.prefill(&ids, 3, &bt).expect("prefill");
        Self::decode_single(rt.as_mut(), 1, 3, 4, &bt); // pollute
        rt.reset_cache().expect("reset");
        let b = rt.prefill(&ids, 3, &bt).expect("prefill after reset");
        self.assert_close(&a.logits, &b.logits, "reset_cache replay");
    }

    /// The same sequence decoded through two different compiled batch
    /// sizes (padding the extra slots) produces the same live-row logits:
    /// the static-shape menu is semantically transparent.
    pub fn batch_menu_is_transparent(&self) {
        let batches = self.fresh().compiled_batches();
        if batches.len() < 2 {
            return; // a single compiled batch size: nothing to compare
        }
        let (small, large) = (batches[0], batches[1]);

        let mut results = Vec::new();
        for b in [small, large] {
            let mut rt = self.fresh();
            let mc = rt.config().clone();
            let mp = mc.max_pages_per_seq();
            let chunk = rt.compiled_chunks()[0];
            let mut bt = vec![0i32; mp];
            bt[0] = 1;
            rt.prefill(&padded(&[5, 6], chunk), 2, &bt).expect("prefill");
            let mut ids = vec![0i32; b];
            ids[0] = 9;
            let mut positions = vec![0i32; b];
            positions[0] = 2;
            let mut lens = vec![0i32; b];
            lens[0] = 3;
            let mut tables = vec![0i32; b * mp];
            tables[..mp].copy_from_slice(&bt);
            let out = rt.decode(&ids, &positions, &lens, &tables).expect("decode");
            results.push(out.logits[..mc.vocab_size].to_vec());
        }
        self.assert_close(&results[0], &results[1], "b=small vs b=large live row");
    }

    /// Two sequences with identical token prefixes but different page
    /// assignments see identical logits: the KV contract is
    /// content-addressed through the block table, page *ids* never leak.
    pub fn logits_address_page_contents_not_page_ids(&self) {
        let mut rt = self.fresh();
        let mc = rt.config().clone();
        let chunk = mc.pick_chunk(9).expect("a chunk holding 9 tokens");
        let ids = padded(&[21, 22, 23, 24, 25, 26, 27, 28, 29], chunk);

        let mut bt_a = vec![0i32; mc.max_pages_per_seq()];
        bt_a[0] = 1;
        bt_a[1] = 2;
        let a = rt.prefill(&ids, 9, &bt_a).expect("prefill a");

        let mut bt_b = vec![0i32; mc.max_pages_per_seq()];
        bt_b[0] = 5;
        bt_b[1] = 6;
        let b = rt.prefill(&ids, 9, &bt_b).expect("prefill b");
        self.assert_close(&a.logits, &b.logits, "same tokens, different pages");
    }

    /// The positioned-prefill contract: a prompt fed as several
    /// `prefill_chunk` slices — including a split that straddles a page
    /// boundary — produces the same last-token logits as one
    /// whole-prompt call, and the resulting KV state decodes
    /// identically.
    pub fn chunked_prefill_matches_whole_prompt(&self) {
        let probe = self.fresh();
        let mc = probe.config().clone();
        let ps = mc.page_size;
        // A prompt spanning two pages, longer than one page by 3 tokens.
        let len = ps + 3;
        let prompt: Vec<i32> = (0..len as i32).map(|i| 30 + i).collect();
        let chunk = mc.pick_chunk(len).expect("prompt fits largest chunk");
        let mut bt = vec![0i32; mc.max_pages_per_seq()];
        bt[0] = 1;
        bt[1] = 2;

        let mut whole = self.fresh();
        let want = whole.prefill(&padded(&prompt, chunk), len, &bt).expect("whole").logits;
        let want_next = Self::decode_single(whole.as_mut(), 77, len as i32, len as i32 + 1, &bt);

        for splits in [vec![1, len - 1], vec![ps, 3], vec![ps - 1, 2, 2]] {
            assert_eq!(splits.iter().sum::<usize>(), len);
            let mut rt = self.fresh();
            let mut start = 0usize;
            let mut last = Vec::new();
            for n in splits.iter().copied() {
                let c = rt.config().pick_chunk(n).expect("chunk for slice");
                let ids = padded(&prompt[start..start + n], c);
                last = rt.prefill_chunk(&ids, start, n, &bt).expect("chunk").logits;
                start += n;
            }
            self.assert_close(&want, &last, &format!("chunked {splits:?} vs whole"));
            let next = Self::decode_single(rt.as_mut(), 77, len as i32, len as i32 + 1, &bt);
            self.assert_close(&want_next, &next, &format!("decode after chunked {splits:?}"));
        }
    }

    /// The prefix-skip contract: a chunk starting past position 0 reads
    /// the resident pages below it — pages another sequence's prefill
    /// wrote (the prefix-cache reuse shape) — instead of requiring a
    /// rewrite.
    pub fn chunked_prefill_reads_resident_prefix_pages(&self) {
        let probe = self.fresh();
        let mc = probe.config().clone();
        let ps = mc.page_size;
        let shared: Vec<i32> = (0..ps as i32).map(|i| 100 + i).collect();
        let suffix = [3i32, 4];
        let mut full = shared.clone();
        full.extend_from_slice(&suffix);
        let len = full.len();
        let chunk = mc.pick_chunk(len).expect("prompt fits largest chunk");

        // Baseline: the full prompt, whole-prompt prefilled on its own pages.
        let mut baseline = self.fresh();
        let mut bt_base = vec![0i32; mc.max_pages_per_seq()];
        bt_base[0] = 5;
        bt_base[1] = 6;
        let want = baseline.prefill(&padded(&full, chunk), len, &bt_base).expect("whole").logits;

        // Reuse shape: sequence A prefills the shared first page; B's
        // table points at A's page and B prefills *only* its suffix,
        // starting at the page boundary.
        let mut rt = self.fresh();
        let mut bt_a = vec![0i32; mc.max_pages_per_seq()];
        bt_a[0] = 1;
        bt_a[1] = 2;
        let c_a = mc.pick_chunk(ps).expect("page-sized chunk");
        rt.prefill_chunk(&padded(&shared, c_a), 0, ps, &bt_a).expect("seq a");

        let mut bt_b = vec![0i32; mc.max_pages_per_seq()];
        bt_b[0] = 1; // A's page, reused
        bt_b[1] = 3; // B's own page for the suffix
        let c_b = mc.pick_chunk(suffix.len()).expect("suffix chunk");
        let got = rt
            .prefill_chunk(&padded(&suffix, c_b), ps, suffix.len(), &bt_b)
            .expect("suffix chunk over reused page")
            .logits;
        self.assert_close(&want, &got, "prefix-skip over a reused page");
    }

    /// The preemption-recompute contract: after the KV pool is wiped
    /// (`reset_cache`, the backend-level analog of evicting a sequence's
    /// pages), replaying the full token history — prompt plus
    /// already-emitted tokens — through positioned `prefill_chunk` calls
    /// onto *different* pages rebuilds a state from which decode
    /// continues exactly as the uninterrupted chain would have.
    pub fn recompute_after_reset_matches_uninterrupted_chain(&self) {
        let probe = self.fresh();
        let mc = probe.config().clone();
        let ps = mc.page_size;
        let prompt: Vec<i32> = (0..(ps + 2) as i32).map(|i| 60 + i).collect();
        let len = prompt.len();
        let chunk = mc.pick_chunk(len).expect("prompt chunk");
        let mut bt = vec![0i32; mc.max_pages_per_seq()];
        bt[0] = 1;
        bt[1] = 2;

        // Uninterrupted chain: prefill, then two decode steps.
        let mut rt = self.fresh();
        rt.prefill(&padded(&prompt, chunk), len, &bt).expect("prefill");
        Self::decode_single(rt.as_mut(), 90, len as i32, len as i32 + 1, &bt);
        let want = Self::decode_single(rt.as_mut(), 91, len as i32 + 1, len as i32 + 2, &bt);

        // Preempted shape: pages lost, history recomputed in chunks that
        // straddle the page boundary, onto a different page assignment.
        rt.reset_cache().expect("reset");
        let mut history = prompt.clone();
        history.push(90);
        let mut bt2 = vec![0i32; mc.max_pages_per_seq()];
        bt2[0] = 3;
        bt2[1] = 4;
        let split = ps - 1;
        for (start, part) in [(0usize, &history[..split]), (split, &history[split..])] {
            let c = mc.pick_chunk(part.len()).expect("resume chunk");
            rt.prefill_chunk(&padded(part, c), start, part.len(), &bt2)
                .expect("recompute chunk");
        }
        let got = Self::decode_single(rt.as_mut(), 91, len as i32 + 1, len as i32 + 2, &bt2);
        self.assert_close(&want, &got, "decode after recompute vs uninterrupted chain");
    }

    /// The speculative-verification contract: `verify_chunk` over a run
    /// of tokens returns, row for row, the logits that sequential
    /// single-row decode calls over the same tokens produce — and leaves
    /// the KV state equally usable (a decode after either path agrees).
    pub fn verify_chunk_matches_sequential_decode(&self) {
        let probe = self.fresh();
        let mc = probe.config().clone();
        let prompt = [40i32, 41, 42];
        let run = [50i32, 51, 52, 53];
        let n = run.len();
        let chunk = mc.pick_chunk(prompt.len()).expect("prompt chunk");
        let mut bt = vec![0i32; mc.max_pages_per_seq()];
        bt[0] = 1;
        bt[1] = 2;

        // Baseline: the run scored by one decode call per token.
        let mut seq = self.fresh();
        seq.prefill(&padded(&prompt, chunk), prompt.len(), &bt).expect("prefill");
        let mut want = Vec::new();
        for (i, &tok) in run.iter().enumerate() {
            let pos = (prompt.len() + i) as i32;
            want.push(Self::decode_single(seq.as_mut(), tok, pos, pos + 1, &bt));
        }

        // One verify_chunk call over the whole run.
        let mut rt = self.fresh();
        rt.prefill(&padded(&prompt, chunk), prompt.len(), &bt).expect("prefill");
        let vc = mc.pick_chunk(n).expect("run chunk");
        let out = rt
            .verify_chunk(&padded(&run, vc), prompt.len(), n, &bt)
            .expect("verify_chunk");
        assert_eq!(out.logits.len(), n * mc.vocab_size, "verify must return [n, vocab]");
        for (i, want_row) in want.iter().enumerate() {
            let got = &out.logits[i * mc.vocab_size..(i + 1) * mc.vocab_size];
            self.assert_close(want_row, got, &format!("verify row {i} vs sequential decode"));
        }

        // The run's KV landed: both instances decode the next position
        // identically.
        let pos = (prompt.len() + n) as i32;
        let after_seq = Self::decode_single(seq.as_mut(), 60, pos, pos + 1, &bt);
        let after_vc = Self::decode_single(rt.as_mut(), 60, pos, pos + 1, &bt);
        self.assert_close(&after_seq, &after_vc, "decode after verify vs after sequential");
    }

    /// The copy-on-write fork contract (backends advertising
    /// `supports_page_copy`): after one prompt prefill, a branch whose
    /// table shares the full pages and owns a `copy_page` duplicate of
    /// the partial tail page decodes exactly like an independent
    /// sequence that prefilled the same prompt on its own pages — and
    /// divergent appends on the two branches never bleed into each
    /// other through the shared page.
    pub fn forked_family_decodes_like_independent_sequences(&self) {
        let mut rt = self.fresh();
        if !rt.supports_page_copy() {
            assert!(rt.copy_page(1, 2).is_err(), "copy_page must error when unsupported");
            return;
        }
        let mc = rt.config().clone();
        let ps = mc.page_size;
        // A prompt with one full shared page and a 2-token partial tail.
        let prompt: Vec<i32> = (0..(ps + 2) as i32).map(|i| 70 + i).collect();
        let len = prompt.len() as i32;
        let chunk = mc.pick_chunk(prompt.len()).expect("prompt chunk");

        // Parent on pages [1, 2]; the fork shares page 1 and copies the
        // tail page 2 -> 3.
        let mut bt_parent = vec![0i32; mc.max_pages_per_seq()];
        bt_parent[0] = 1;
        bt_parent[1] = 2;
        rt.prefill(&padded(&prompt, chunk), prompt.len(), &bt_parent).expect("prefill");
        rt.copy_page(2, 3).expect("tail page copy");
        let mut bt_child = bt_parent.clone();
        bt_child[1] = 3;

        // Diverge: parent appends 90, the fork appends 91 — both writing
        // position `len`, which lands in their private tail pages.
        let parent_t1 = Self::decode_single(rt.as_mut(), 90, len, len + 1, &bt_parent);
        let child_t1 = Self::decode_single(rt.as_mut(), 91, len, len + 1, &bt_child);
        Self::assert_far(&parent_t1, &child_t1, "diverged branches");
        // Chain one more step each; reads cross the shared/private split.
        let parent_t2 = Self::decode_single(rt.as_mut(), 92, len + 1, len + 2, &bt_parent);
        let child_t2 = Self::decode_single(rt.as_mut(), 93, len + 1, len + 2, &bt_child);

        // Baselines: independent sequences with the same histories on
        // disjoint pages, no sharing anywhere.
        let families = [([90i32, 92], [parent_t1, parent_t2]), ([91, 93], [child_t1, child_t2])];
        for (history, forked) in families {
            let mut solo = self.fresh();
            let mut bt = vec![0i32; mc.max_pages_per_seq()];
            bt[0] = 5;
            bt[1] = 6;
            solo.prefill(&padded(&prompt, chunk), prompt.len(), &bt).expect("prefill");
            let t1 = Self::decode_single(solo.as_mut(), history[0], len, len + 1, &bt);
            let t2 = Self::decode_single(solo.as_mut(), history[1], len + 1, len + 2, &bt);
            self.assert_close(&t1, &forked[0], &format!("fork vs solo, token {}", history[0]));
            self.assert_close(&t2, &forked[1], &format!("fork vs solo, token {}", history[1]));
        }
    }
}
