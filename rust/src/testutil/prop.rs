//! Seeded property-test runner with PCG64 randomness.

/// PCG-XSH-RR 64/32 — small, fast, good-enough statistics for tests and
/// the sampler (crate::sampler::rng reuses it).
#[derive(Clone, Debug)]
pub struct PropRng {
    state: u64,
    inc: u64,
}

impl PropRng {
    pub fn new(seed: u64) -> Self {
        let mut rng = Self { state: 0, inc: (seed << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n). n == 0 returns 0.
    pub fn range(&mut self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        (self.u64() % n as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.u64() % ((hi - lo + 1) as u64)) as i64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u32() & 1 == 1
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Random string (mixed ASCII + some multibyte), length <= max_len.
    pub fn string(&mut self, max_len: usize) -> String {
        let len = self.range(max_len + 1);
        (0..len)
            .map(|_| match self.range(20) {
                0 => '\\',
                1 => '"',
                2 => '\n',
                3 => 'é',
                4 => '日',
                5 => '😀',
                _ => (b' ' + (self.range(95) as u8)) as char,
            })
            .collect()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(items.len())]
    }
}

/// Runs a property `iters` times with derived seeds; panics with the seed
/// of the first failing case.
pub struct Runner {
    name: &'static str,
    iters: u64,
}

impl Runner {
    pub fn new(name: &'static str, iters: u64) -> Self {
        Self { name, iters }
    }

    pub fn run(&self, mut prop: impl FnMut(&mut PropRng) -> Result<(), String>) {
        // Explicit seed reproduces a single failing case.
        if let Ok(seed) = std::env::var("WEBLLM_PROP_SEED") {
            let seed: u64 = seed.parse().expect("WEBLLM_PROP_SEED must be a u64");
            let mut rng = PropRng::new(seed);
            if let Err(msg) = prop(&mut rng) {
                panic!("[{}] failed with seed {}: {}", self.name, seed, msg);
            }
            return;
        }
        for i in 0..self.iters {
            let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(i + 1) ^ 0xD1B54A32D192ED03;
            let mut rng = PropRng::new(seed);
            if let Err(msg) = prop(&mut rng) {
                panic!(
                    "[{}] failed at iter {i} (reproduce: WEBLLM_PROP_SEED={seed}): {msg}",
                    self.name
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = PropRng::new(7);
        let mut b = PropRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn rng_f64_in_unit_interval() {
        let mut rng = PropRng::new(3);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn rng_range_bounds() {
        let mut rng = PropRng::new(9);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(rng.range(n) < n);
            }
        }
        assert_eq!(rng.range(0), 0);
    }

    #[test]
    fn runner_reports_seed_on_failure() {
        let result = std::panic::catch_unwind(|| {
            Runner::new("always_fails", 1).run(|_| Err("boom".into()));
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("WEBLLM_PROP_SEED="), "{msg}");
    }
}
