//! Independent JSON-Schema instance validator — the conformance-test
//! oracle.
//!
//! Deliberately shares no machinery with `grammar::json_schema`: keywords
//! are applied conjunctively per the spec (not compiled to a byte
//! grammar), `pattern` uses an unanchored substring search over a
//! Thompson-NFA regex engine (no backtracking), string lengths count
//! Unicode code points, and object key order / whitespace don't matter.
//! The grammar emits a *canonical subset* of each schema's language, so
//! the differential contract is one-sided: everything the grammar accepts
//! must validate here, and anything rejected here must be rejected by the
//! grammar. The only shared artifact is [`format_pattern`] — both sides
//! must agree on what, say, a `uuid` looks like.
//!
//! Unknown keywords are ignored (annotation semantics); keywords with
//! shapes we cannot judge return `Err` so a test can't silently pass.

use crate::grammar::format_pattern;
use crate::json::Value;

const MAX_DEPTH: usize = 256;

/// Validate `instance` against `schema` (draft 2020-12 subset).
/// `Ok(true)` / `Ok(false)` = verdict; `Err` = the schema itself is
/// malformed or outside the supported subset.
pub fn validate(schema: &Value, instance: &Value) -> Result<bool, String> {
    check(schema, schema, instance, 0)
}

fn check(root: &Value, schema: &Value, inst: &Value, depth: usize) -> Result<bool, String> {
    if depth > MAX_DEPTH {
        return Err("schema recursion too deep".into());
    }
    let o = match schema {
        Value::Bool(b) => return Ok(*b),
        Value::Object(o) => o,
        _ => return Err("schema must be an object or boolean".into()),
    };

    if let Some(r) = o.get("$ref") {
        let path = r.as_str().ok_or("$ref must be a string")?;
        let target = deref(root, path)?;
        if !check(root, target, inst, depth + 1)? {
            return Ok(false);
        }
    }
    if let Some(t) = o.get("type") {
        if !type_ok(t, inst)? {
            return Ok(false);
        }
    }
    if let Some(c) = o.get("const") {
        if inst != c {
            return Ok(false);
        }
    }
    if let Some(e) = o.get("enum") {
        let list = e.as_array().ok_or("'enum' must be an array")?;
        if !list.iter().any(|v| v == inst) {
            return Ok(false);
        }
    }
    if let Some(l) = o.get("allOf") {
        for s in l.as_array().ok_or("'allOf' must be an array")? {
            if !check(root, s, inst, depth + 1)? {
                return Ok(false);
            }
        }
    }
    if let Some(l) = o.get("anyOf") {
        let list = l.as_array().ok_or("'anyOf' must be an array")?;
        let mut any = false;
        for s in list {
            if check(root, s, inst, depth + 1)? {
                any = true;
            }
        }
        if !any {
            return Ok(false);
        }
    }
    if let Some(l) = o.get("oneOf") {
        // Exactly one branch must validate (the keyword the grammar can
        // only express for provably disjoint branches).
        let list = l.as_array().ok_or("'oneOf' must be an array")?;
        let mut hits = 0;
        for s in list {
            if check(root, s, inst, depth + 1)? {
                hits += 1;
            }
        }
        if hits != 1 {
            return Ok(false);
        }
    }

    match inst {
        Value::String(s) => {
            let len = s.chars().count();
            if let Some(m) = o.get("minLength") {
                if len < m.as_usize().ok_or("'minLength' must be an integer")? {
                    return Ok(false);
                }
            }
            if let Some(m) = o.get("maxLength") {
                if len > m.as_usize().ok_or("'maxLength' must be an integer")? {
                    return Ok(false);
                }
            }
            if let Some(p) = o.get("pattern") {
                let p = p.as_str().ok_or("'pattern' must be a string")?;
                if !regex_matches(p, s, false)? {
                    return Ok(false);
                }
            }
            if let Some(f) = o.get("format") {
                let f = f.as_str().ok_or("'format' must be a string")?;
                if let Some(p) = format_pattern(f) {
                    if !regex_matches(p, s, true)? {
                        return Ok(false);
                    }
                }
            }
        }
        Value::Number(n) => {
            if let Some(b) = o.get("minimum") {
                if *n < b.as_f64().ok_or("'minimum' must be a number")? {
                    return Ok(false);
                }
            }
            if let Some(b) = o.get("exclusiveMinimum") {
                if *n <= b.as_f64().ok_or("'exclusiveMinimum' must be a number")? {
                    return Ok(false);
                }
            }
            if let Some(b) = o.get("maximum") {
                if *n > b.as_f64().ok_or("'maximum' must be a number")? {
                    return Ok(false);
                }
            }
            if let Some(b) = o.get("exclusiveMaximum") {
                if *n >= b.as_f64().ok_or("'exclusiveMaximum' must be a number")? {
                    return Ok(false);
                }
            }
        }
        Value::Object(io) => {
            if let Some(r) = o.get("required") {
                for name in r.as_array().ok_or("'required' must be an array")? {
                    let name = name.as_str().ok_or("'required' entries must be strings")?;
                    if !io.contains_key(name) {
                        return Ok(false);
                    }
                }
            }
            let props = o.get("properties");
            if let Some(p) = props {
                let p = p.as_object().ok_or("'properties' must be an object")?;
                for (k, sub) in p.iter() {
                    if let Some(v) = io.get(k) {
                        if !check(root, sub, v, depth + 1)? {
                            return Ok(false);
                        }
                    }
                }
            }
            if let Some(ap) = o.get("additionalProperties") {
                let declared = |k: &str| {
                    props
                        .and_then(Value::as_object)
                        .map_or(false, |p| p.contains_key(k))
                };
                for (k, v) in io.iter() {
                    if declared(k) {
                        continue;
                    }
                    match ap {
                        Value::Bool(false) => return Ok(false),
                        Value::Bool(true) => {}
                        sub => {
                            if !check(root, sub, v, depth + 1)? {
                                return Ok(false);
                            }
                        }
                    }
                }
            }
        }
        Value::Array(items) => {
            if let Some(m) = o.get("minItems") {
                if items.len() < m.as_usize().ok_or("'minItems' must be an integer")? {
                    return Ok(false);
                }
            }
            if let Some(m) = o.get("maxItems") {
                if items.len() > m.as_usize().ok_or("'maxItems' must be an integer")? {
                    return Ok(false);
                }
            }
            let prefix = match o.get("prefixItems") {
                Some(p) => p.as_array().ok_or("'prefixItems' must be an array")?.as_slice(),
                None => &[],
            };
            for (i, v) in items.iter().enumerate() {
                if i < prefix.len() {
                    if !check(root, &prefix[i], v, depth + 1)? {
                        return Ok(false);
                    }
                } else if let Some(sub) = o.get("items") {
                    match sub {
                        Value::Bool(false) => return Ok(false),
                        _ => {
                            if !check(root, sub, v, depth + 1)? {
                                return Ok(false);
                            }
                        }
                    }
                }
            }
        }
        _ => {}
    }
    Ok(true)
}

fn deref<'a>(root: &'a Value, path: &str) -> Result<&'a Value, String> {
    let target = path
        .strip_prefix("#/$defs/")
        .or_else(|| path.strip_prefix("#/definitions/"))
        .ok_or_else(|| format!("unsupported $ref '{path}'"))?;
    root.get("$defs")
        .or_else(|| root.get("definitions"))
        .and_then(|d| d.get(target))
        .ok_or_else(|| format!("unresolved $ref '{path}'"))
}

fn type_ok(t: &Value, inst: &Value) -> Result<bool, String> {
    match t {
        Value::String(s) => Ok(one_type_ok(s, inst)),
        Value::Array(ts) => {
            for t in ts {
                let s = t.as_str().ok_or("'type' array entries must be strings")?;
                if one_type_ok(s, inst) {
                    return Ok(true);
                }
            }
            Ok(false)
        }
        _ => Err("'type' must be a string or array of strings".into()),
    }
}

fn one_type_ok(t: &str, inst: &Value) -> bool {
    match t {
        "null" => inst.is_null(),
        "boolean" => matches!(inst, Value::Bool(_)),
        "string" => matches!(inst, Value::String(_)),
        "number" => matches!(inst, Value::Number(_)),
        "integer" => matches!(inst, Value::Number(n) if n.fract() == 0.0 && n.is_finite()),
        "object" => matches!(inst, Value::Object(_)),
        "array" => matches!(inst, Value::Array(_)),
        _ => false,
    }
}

// --- regex engine (Thompson NFA, Pike-style set simulation) --------------
//
// Standard ECMA-ish semantics over Unicode scalar values: `.` is
// any-but-newline, classes are true complements, `pattern` searches
// unanchored unless the pattern leads with `^` / ends with `$`. This is
// intentionally a different construction than `grammar::regex` (byte-level
// CFG, always anchored, JSON-safe alphabet) so the two implementations
// cross-check each other.

const MAX_INSTS: usize = 100_000;
const MAX_COUNT: usize = 1024;

#[derive(Clone, Debug)]
struct Class {
    ranges: Vec<(u32, u32)>,
    negated: bool,
}

impl Class {
    fn matches(&self, c: char) -> bool {
        let c = c as u32;
        let inside = self.ranges.iter().any(|&(lo, hi)| lo <= c && c <= hi);
        inside != self.negated
    }
}

enum Re {
    Empty,
    Char(Class),
    Concat(Vec<Re>),
    Alt(Vec<Re>),
    Star(Box<Re>),
    Plus(Box<Re>),
    Opt(Box<Re>),
    Repeat(Box<Re>, usize, Option<usize>),
}

enum Inst {
    Char(Class),
    Split(usize, usize),
    Jmp(usize),
    Match,
}

/// Whether `pattern` matches `text`: full match when `anchored`
/// (format semantics), else substring search (pattern semantics, with
/// leading `^` / trailing `$` respected).
pub fn regex_matches(pattern: &str, text: &str, anchored: bool) -> Result<bool, String> {
    let cs: Vec<char> = pattern.chars().collect();
    let mut p = Pat { cs: &cs, pos: 0 };
    let ast = p.alt()?;
    if p.pos < cs.len() {
        return Err(format!("unexpected '{}' at {}", cs[p.pos], p.pos));
    }
    let anchor_start = anchored || pattern.starts_with('^');
    let anchor_end = anchored || ends_with_anchor(&cs);
    let mut c = Codegen { insts: Vec::new() };
    c.emit(&ast)?;
    c.insts.push(Inst::Match);
    let chars: Vec<char> = text.chars().collect();
    Ok(run(&c.insts, &chars, anchor_start, anchor_end))
}

fn ends_with_anchor(cs: &[char]) -> bool {
    if cs.last() != Some(&'$') {
        return false;
    }
    // `\$` is a literal dollar; count the preceding backslash run.
    let mut backslashes = 0;
    for &c in cs[..cs.len() - 1].iter().rev() {
        if c == '\\' {
            backslashes += 1;
        } else {
            break;
        }
    }
    backslashes % 2 == 0
}

struct Pat<'a> {
    cs: &'a [char],
    pos: usize,
}

impl<'a> Pat<'a> {
    fn peek(&self) -> Option<char> {
        self.cs.get(self.pos).copied()
    }

    fn alt(&mut self) -> Result<Re, String> {
        let mut branches = vec![self.concat()?];
        while self.peek() == Some('|') {
            self.pos += 1;
            branches.push(self.concat()?);
        }
        Ok(if branches.len() == 1 { branches.pop().unwrap() } else { Re::Alt(branches) })
    }

    fn concat(&mut self) -> Result<Re, String> {
        let mut seq = Vec::new();
        while let Some(c) = self.peek() {
            if c == '|' || c == ')' {
                break;
            }
            let atom = self.atom()?;
            seq.push(self.postfix(atom)?);
        }
        Ok(match seq.len() {
            0 => Re::Empty,
            1 => seq.pop().unwrap(),
            _ => Re::Concat(seq),
        })
    }

    fn atom(&mut self) -> Result<Re, String> {
        let c = self.cs[self.pos];
        self.pos += 1;
        match c {
            '(' => {
                if self.peek() == Some('?') {
                    if self.cs.get(self.pos + 1) == Some(&':') {
                        self.pos += 2;
                    } else {
                        return Err("unsupported (?...) group".into());
                    }
                }
                let inner = self.alt()?;
                if self.peek() != Some(')') {
                    return Err("unclosed group".into());
                }
                self.pos += 1;
                Ok(inner)
            }
            '[' => self.class(),
            '.' => Ok(Re::Char(Class { ranges: vec![('\n' as u32, '\n' as u32)], negated: true })),
            // Anchors apply at the pattern edges (handled by the caller);
            // elsewhere they are epsilon here.
            '^' | '$' => Ok(Re::Empty),
            '\\' => {
                let e = self.escape(false)?;
                Ok(Re::Char(e))
            }
            '*' | '+' | '?' => Err(format!("dangling quantifier '{c}'")),
            _ => Ok(Re::Char(lit(c))),
        }
    }

    fn postfix(&mut self, atom: Re) -> Result<Re, String> {
        match self.peek() {
            Some('*') => {
                self.pos += 1;
                Ok(Re::Star(Box::new(atom)))
            }
            Some('+') => {
                self.pos += 1;
                Ok(Re::Plus(Box::new(atom)))
            }
            Some('?') => {
                self.pos += 1;
                Ok(Re::Opt(Box::new(atom)))
            }
            Some('{') => {
                let save = self.pos;
                match self.counts() {
                    Ok((min, max)) => Ok(Re::Repeat(Box::new(atom), min, max)),
                    // Not a quantifier — `{` is a literal atom.
                    Err(_) => {
                        self.pos = save;
                        Ok(atom)
                    }
                }
            }
            _ => Ok(atom),
        }
    }

    fn counts(&mut self) -> Result<(usize, Option<usize>), String> {
        debug_assert_eq!(self.peek(), Some('{'));
        self.pos += 1;
        let min = self.number()?;
        let out = match self.peek() {
            Some('}') => (min, Some(min)),
            Some(',') => {
                self.pos += 1;
                if self.peek() == Some('}') {
                    (min, None)
                } else {
                    let max = self.number()?;
                    if max < min {
                        return Err("repetition max < min".into());
                    }
                    (min, Some(max))
                }
            }
            _ => return Err("malformed repetition".into()),
        };
        if self.peek() != Some('}') {
            return Err("malformed repetition".into());
        }
        self.pos += 1;
        Ok(out)
    }

    fn number(&mut self) -> Result<usize, String> {
        let start = self.pos;
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.pos == start {
            return Err("expected a count".into());
        }
        let n: usize = self.cs[start..self.pos]
            .iter()
            .collect::<String>()
            .parse()
            .map_err(|_| "count overflow".to_string())?;
        if n > MAX_COUNT {
            return Err(format!("count exceeds {MAX_COUNT}"));
        }
        Ok(n)
    }

    fn class(&mut self) -> Result<Re, String> {
        let negated = if self.peek() == Some('^') {
            self.pos += 1;
            true
        } else {
            false
        };
        let mut ranges: Vec<(u32, u32)> = Vec::new();
        loop {
            let c = self.peek().ok_or("unclosed character class")?;
            if c == ']' {
                self.pos += 1;
                break;
            }
            self.pos += 1;
            let lo = if c == '\\' {
                let e = self.escape(true)?;
                if e.ranges.len() != 1 || e.ranges[0].0 != e.ranges[0].1 {
                    // Multi-range escape (\d, \w, \s): no range syntax.
                    ranges.extend(e.ranges);
                    continue;
                }
                e.ranges[0].0
            } else {
                c as u32
            };
            // `a-z` range (a trailing `-` is a literal).
            if self.peek() == Some('-') && self.cs.get(self.pos + 1).map_or(false, |&c| c != ']') {
                self.pos += 1;
                let hc = self.cs[self.pos];
                self.pos += 1;
                let hi = if hc == '\\' {
                    let e = self.escape(true)?;
                    if e.ranges.len() != 1 || e.ranges[0].0 != e.ranges[0].1 {
                        return Err("class escape cannot end a range".into());
                    }
                    e.ranges[0].0
                } else {
                    hc as u32
                };
                if hi < lo {
                    return Err("reversed class range".into());
                }
                ranges.push((lo, hi));
            } else {
                ranges.push((lo, lo));
            }
        }
        if ranges.is_empty() && !negated {
            return Err("empty character class".into());
        }
        Ok(Re::Char(Class { ranges, negated }))
    }

    /// After a `\`. `in_class` only affects which metacharacters make
    /// sense, not the result shape.
    fn escape(&mut self, in_class: bool) -> Result<Class, String> {
        let c = self.peek().ok_or("trailing backslash")?;
        self.pos += 1;
        let single = |c: char| Class { ranges: vec![(c as u32, c as u32)], negated: false };
        Ok(match c {
            'd' => Class { ranges: vec![('0' as u32, '9' as u32)], negated: false },
            'D' => Class { ranges: vec![('0' as u32, '9' as u32)], negated: true },
            'w' => word_class(false),
            'W' => word_class(true),
            's' => space_class(false),
            'S' => space_class(true),
            'n' => single('\n'),
            't' => single('\t'),
            'r' => single('\r'),
            'f' => single('\u{0C}'),
            'v' => single('\u{0B}'),
            '0' => single('\0'),
            'u' | 'x' => return Err(format!("unsupported escape '\\{c}'")),
            _ => {
                let _ = in_class;
                single(c)
            }
        })
    }
}

fn lit(c: char) -> Class {
    Class { ranges: vec![(c as u32, c as u32)], negated: false }
}

fn word_class(negated: bool) -> Class {
    Class {
        ranges: vec![
            ('0' as u32, '9' as u32),
            ('A' as u32, 'Z' as u32),
            ('_' as u32, '_' as u32),
            ('a' as u32, 'z' as u32),
        ],
        negated,
    }
}

fn space_class(negated: bool) -> Class {
    Class {
        ranges: vec![(0x09, 0x0D), (' ' as u32, ' ' as u32)],
        negated,
    }
}

struct Codegen {
    insts: Vec<Inst>,
}

impl Codegen {
    fn emit(&mut self, re: &Re) -> Result<(), String> {
        if self.insts.len() > MAX_INSTS {
            return Err("pattern too large".into());
        }
        match re {
            Re::Empty => {}
            Re::Char(c) => self.insts.push(Inst::Char(c.clone())),
            Re::Concat(v) => {
                for r in v {
                    self.emit(r)?;
                }
            }
            Re::Alt(branches) => {
                let mut jmps = Vec::new();
                for (i, b) in branches.iter().enumerate() {
                    if i + 1 < branches.len() {
                        let sp = self.insts.len();
                        self.insts.push(Inst::Split(sp + 1, 0));
                        self.emit(b)?;
                        jmps.push(self.insts.len());
                        self.insts.push(Inst::Jmp(0));
                        let next = self.insts.len();
                        if let Inst::Split(_, alt) = &mut self.insts[sp] {
                            *alt = next;
                        }
                    } else {
                        self.emit(b)?;
                    }
                }
                let end = self.insts.len();
                for j in jmps {
                    if let Inst::Jmp(t) = &mut self.insts[j] {
                        *t = end;
                    }
                }
            }
            Re::Star(r) => {
                let sp = self.insts.len();
                self.insts.push(Inst::Split(sp + 1, 0));
                self.emit(r)?;
                self.insts.push(Inst::Jmp(sp));
                let end = self.insts.len();
                if let Inst::Split(_, alt) = &mut self.insts[sp] {
                    *alt = end;
                }
            }
            Re::Plus(r) => {
                let start = self.insts.len();
                self.emit(r)?;
                let sp = self.insts.len();
                self.insts.push(Inst::Split(start, sp + 1));
            }
            Re::Opt(r) => {
                let sp = self.insts.len();
                self.insts.push(Inst::Split(sp + 1, 0));
                self.emit(r)?;
                let end = self.insts.len();
                if let Inst::Split(_, alt) = &mut self.insts[sp] {
                    *alt = end;
                }
            }
            Re::Repeat(r, min, max) => {
                for _ in 0..*min {
                    self.emit(r)?;
                }
                match max {
                    None => self.emit_star(r)?,
                    // `r? r? ...` — copies are identical, so sequential
                    // optionals count the same as nested ones.
                    Some(max) => {
                        for _ in *min..*max {
                            let sp = self.insts.len();
                            self.insts.push(Inst::Split(sp + 1, 0));
                            self.emit(r)?;
                            let end = self.insts.len();
                            if let Inst::Split(_, alt) = &mut self.insts[sp] {
                                *alt = end;
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn emit_star(&mut self, r: &Re) -> Result<(), String> {
        let sp = self.insts.len();
        self.insts.push(Inst::Split(sp + 1, 0));
        self.emit(r)?;
        self.insts.push(Inst::Jmp(sp));
        let end = self.insts.len();
        if let Inst::Split(_, alt) = &mut self.insts[sp] {
            *alt = end;
        }
        Ok(())
    }
}

fn add_closure(insts: &[Inst], set: &mut Vec<bool>, start: usize) {
    let mut work = vec![start];
    while let Some(i) = work.pop() {
        if set[i] {
            continue;
        }
        set[i] = true;
        match &insts[i] {
            Inst::Split(a, b) => {
                work.push(*a);
                work.push(*b);
            }
            Inst::Jmp(t) => work.push(*t),
            _ => {}
        }
    }
}

fn has_match(insts: &[Inst], set: &[bool]) -> bool {
    set.iter()
        .enumerate()
        .any(|(i, &on)| on && matches!(insts[i], Inst::Match))
}

fn run(insts: &[Inst], text: &[char], anchor_start: bool, anchor_end: bool) -> bool {
    let mut cur = vec![false; insts.len()];
    add_closure(insts, &mut cur, 0);
    for &c in text {
        if !anchor_end && has_match(insts, &cur) {
            return true;
        }
        if !anchor_start {
            // A new match attempt may begin at this position.
            add_closure(insts, &mut cur, 0);
        }
        let mut next = vec![false; insts.len()];
        for (i, &on) in cur.iter().enumerate() {
            if !on {
                continue;
            }
            if let Inst::Char(cl) = &insts[i] {
                if cl.matches(c) {
                    add_closure(insts, &mut next, i + 1);
                }
            }
        }
        cur = next;
    }
    if !anchor_start && !has_match(insts, &cur) {
        // An empty match at the very end still counts.
        add_closure(insts, &mut cur, 0);
    }
    has_match(insts, &cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn regex_search_vs_anchored() {
        assert!(regex_matches("b+c", "abbcd", false).unwrap());
        assert!(!regex_matches("b+c", "abbcd", true).unwrap());
        assert!(regex_matches("^ab", "abc", false).unwrap());
        assert!(!regex_matches("^bc", "abc", false).unwrap());
        assert!(regex_matches("bc$", "abc", false).unwrap());
        assert!(!regex_matches("ab$", "abc", false).unwrap());
        assert!(regex_matches("a{2,3}", "xaaay", false).unwrap());
        assert!(!regex_matches("^a{2,3}$", "aaaa", false).unwrap());
        assert!(regex_matches("[^0-9]+", "abc", true).unwrap());
        assert!(regex_matches("(ab|cd)+", "abcdab", true).unwrap());
        assert!(regex_matches("\\d{3}", "12345", false).unwrap());
        assert!(regex_matches("", "anything", false).unwrap());
        assert!(regex_matches("x.z", "x№z", true).unwrap());
        assert!(regex_matches("日+", "日日", true).unwrap());
        assert!(regex_matches("(?=a)", "a", false).is_err());
    }

    #[test]
    fn validates_basic_keywords() {
        let schema = parse(
            r#"{"type":"object",
                "properties":{"n":{"type":"integer","minimum":2}},
                "required":["n"],
                "additionalProperties":false}"#,
        )
        .unwrap();
        let yes = parse(r#"{"n":3}"#).unwrap();
        let no_low = parse(r#"{"n":1}"#).unwrap();
        let no_extra = parse(r#"{"n":3,"x":1}"#).unwrap();
        assert!(validate(&schema, &yes).unwrap());
        assert!(!validate(&schema, &no_low).unwrap());
        assert!(!validate(&schema, &no_extra).unwrap());
    }

    #[test]
    fn one_of_is_exactly_one() {
        let schema = parse(
            r#"{"oneOf":[{"type":"integer","minimum":0},
                          {"type":"integer","maximum":10}]}"#,
        )
        .unwrap();
        // 5 matches both branches -> invalid under oneOf.
        assert!(!validate(&schema, &parse("5").unwrap()).unwrap());
        assert!(validate(&schema, &parse("-3").unwrap()).unwrap());
        assert!(validate(&schema, &parse("12").unwrap()).unwrap());
    }

    #[test]
    fn format_is_anchored_pattern_is_searched() {
        let schema = parse(r#"{"type":"string","format":"uuid"}"#).unwrap();
        let ok = parse(r#""123e4567-e89b-12d3-a456-426614174000""#).unwrap();
        let bad = parse(r#""x123e4567-e89b-12d3-a456-426614174000""#).unwrap();
        assert!(validate(&schema, &ok).unwrap());
        assert!(!validate(&schema, &bad).unwrap());

        let schema = parse(r#"{"type":"string","pattern":"[0-9]{3}"}"#).unwrap();
        assert!(validate(&schema, &parse(r#""ab1234""#).unwrap()).unwrap());
        assert!(!validate(&schema, &parse(r#""ab12""#).unwrap()).unwrap());
    }
}
