//! Minimal HTTP/1.1 server + SSE so the engine is literally an endpoint
//! (`POST /v1/chat/completions`), matching the paper's "treat the engine
//! like an endpoint" framing. Std-only: `TcpListener` + a thread per
//! connection; request handling posts to the worker channel.

mod server;
mod sse;

pub use server::{serve, HttpRequest, HttpResponse, ServerConfig};
pub use sse::{parse_sse_body as sse_parse, SseWriter};

#[cfg(test)]
mod tests;
