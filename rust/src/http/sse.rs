//! Server-Sent Events writer (the `stream: true` transport, OpenAI-style
//! `data: {...}` frames terminated by `data: [DONE]`).

use crate::json::{to_string, Value};
use std::io::Write;

pub struct SseWriter<'a, W: Write> {
    out: &'a mut W,
}

impl<'a, W: Write> SseWriter<'a, W> {
    /// Write the SSE response header and return the writer.
    pub fn start(out: &'a mut W) -> std::io::Result<Self> {
        write!(
            out,
            "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
        )?;
        out.flush()?;
        Ok(Self { out })
    }

    pub fn send_json(&mut self, v: &Value) -> std::io::Result<()> {
        write!(self.out, "data: {}\n\n", to_string(v))?;
        self.out.flush()
    }

    pub fn done(&mut self) -> std::io::Result<()> {
        write!(self.out, "data: [DONE]\n\n")?;
        self.out.flush()
    }
}

/// Parse SSE frames out of a raw response body (client side, used by the
/// serve_benchmark driver and tests).
pub fn parse_sse_body(body: &str) -> (Vec<Value>, bool) {
    let mut events = Vec::new();
    let mut done = false;
    for frame in body.split("\n\n") {
        let Some(data) = frame.strip_prefix("data: ") else { continue };
        if data.trim() == "[DONE]" {
            done = true;
        } else if let Ok(v) = crate::json::parse(data.trim()) {
            events.push(v);
        }
    }
    (events, done)
}
