use super::server::{status_text, HttpRequest, HttpResponse};
use super::sse::parse_sse_body;
use crate::json::parse;

#[test]
fn response_serialization() {
    let v = parse(r#"{"ok":true}"#).unwrap();
    let r = HttpResponse::json(200, &v);
    let mut buf = Vec::new();
    r.write_to(&mut buf).unwrap();
    let s = String::from_utf8(buf).unwrap();
    assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
    assert!(s.contains("Content-Type: application/json"));
    assert!(s.contains("Content-Length: 11"));
    assert!(s.ends_with(r#"{"ok":true}"#));
}

#[test]
fn back_pressure_responses_carry_retry_after() {
    let e = crate::api::ApiError::queue_full("waiting queue at capacity");
    let r = HttpResponse::json(e.status, &e.to_json());
    let mut buf = Vec::new();
    r.write_to(&mut buf).unwrap();
    let s = String::from_utf8(buf).unwrap();
    assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{s}");
    assert!(s.contains("Retry-After: 1\r\n"), "{s}");
    // The header block still terminates correctly before the body.
    assert!(s.contains("\r\n\r\n{"), "{s}");

    // Non-429 responses must not grow the header.
    let ok = HttpResponse::json(200, &parse(r#"{"ok":true}"#).unwrap());
    let mut buf = Vec::new();
    ok.write_to(&mut buf).unwrap();
    assert!(!String::from_utf8(buf).unwrap().contains("Retry-After"));
}

#[test]
fn status_texts() {
    assert_eq!(status_text(200), "OK");
    assert_eq!(status_text(404), "Not Found");
    assert_eq!(status_text(500), "Internal Server Error");
    assert_eq!(status_text(418), "Internal Server Error");
}

#[test]
fn header_lookup_case_insensitive() {
    let req = HttpRequest {
        method: "POST".into(),
        path: "/x".into(),
        headers: vec![("Content-Length".into(), "42".into())],
        body: String::new(),
    };
    assert_eq!(req.header("content-length"), Some("42"));
    assert_eq!(req.header("CONTENT-LENGTH"), Some("42"));
    assert_eq!(req.header("x-nope"), None);
}

#[test]
fn sse_frames_are_line_by_line_well_formed() {
    // Round-trip a realistic chunk stream through a strict line-by-line
    // parse: every frame is exactly `data: <json>` + blank line, the
    // stream ends with `data: [DONE]`, and the deltas reassemble the
    // original text.
    let deltas = ["Hel", "lo", ", ", "wor", "ld"];
    let mut buf = Vec::new();
    {
        let mut w = super::sse::SseWriter::start(&mut buf).unwrap();
        for d in deltas {
            let chunk = crate::obj! {
                "object" => "chat.completion.chunk",
                "choices" => vec![crate::obj! {"delta" => crate::obj! {"content" => d}}],
            };
            w.send_json(&chunk).unwrap();
        }
        w.done().unwrap();
    }
    let s = String::from_utf8(buf).unwrap();
    let body = s.split_once("\r\n\r\n").unwrap().1;

    let mut lines = body.lines();
    let mut reassembled = String::new();
    let mut frames = 0;
    let mut done = false;
    while let Some(line) = lines.next() {
        if line.is_empty() {
            continue;
        }
        let data = line.strip_prefix("data: ").expect("frame must start with 'data: '");
        assert!(!done, "no frames allowed after [DONE]");
        if data == "[DONE]" {
            done = true;
        } else {
            frames += 1;
            let v = parse(data).expect("each frame is one complete JSON document");
            if let Some(content) = v
                .get("choices")
                .and_then(|c| c.at(0))
                .and_then(|c| c.get("delta"))
                .and_then(|d| d.get("content"))
                .and_then(crate::json::Value::as_str)
            {
                reassembled.push_str(content);
            }
        }
        assert_eq!(lines.next(), Some(""), "every frame ends with a blank line");
    }
    assert!(done, "stream must terminate with [DONE]");
    assert_eq!(frames, deltas.len());
    assert_eq!(reassembled, "Hello, world");
}

#[test]
fn sse_writer_and_parser_roundtrip() {
    let mut buf = Vec::new();
    {
        let mut w = super::sse::SseWriter::start(&mut buf).unwrap();
        w.send_json(&parse(r#"{"n":1}"#).unwrap()).unwrap();
        w.send_json(&parse(r#"{"n":2}"#).unwrap()).unwrap();
        w.done().unwrap();
    }
    let s = String::from_utf8(buf).unwrap();
    assert!(s.contains("text/event-stream"));
    let body = s.split_once("\r\n\r\n").unwrap().1;
    let (events, done) = parse_sse_body(body);
    assert_eq!(events.len(), 2);
    assert!(done);
    assert_eq!(events[1].get("n").unwrap().as_i64(), Some(2));
}
