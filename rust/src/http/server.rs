//! HTTP/1.1 parsing + the chat-completions endpoint.

use crate::api::{ApiError, ChatCompletionRequest};
use crate::coordinator::messages::FromWorker;
use crate::coordinator::{EngineConfig, ServiceWorkerMLCEngine};
use crate::json::{to_string, Value};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::time::Duration;

const MAX_BODY: usize = 4 << 20;

#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Parse one request from a stream (blocking).
    pub fn read_from(stream: &mut BufReader<TcpStream>) -> Result<Self, String> {
        let mut line = String::new();
        stream.read_line(&mut line).map_err(|e| e.to_string())?;
        if line.is_empty() {
            return Err("connection closed".into());
        }
        let mut parts = line.split_whitespace();
        let method = parts.next().ok_or("bad request line")?.to_string();
        let path = parts.next().ok_or("bad request line")?.to_string();

        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            stream.read_line(&mut h).map_err(|e| e.to_string())?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.push((k.trim().to_string(), v.trim().to_string()));
            }
        }
        let req = Self { method, path, headers, body: String::new() };
        let len: usize = req
            .header("content-length")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        if len > MAX_BODY {
            return Err("body too large".into());
        }
        let mut body = vec![0u8; len];
        stream.read_exact(&mut body).map_err(|e| e.to_string())?;
        let body = String::from_utf8(body).map_err(|e| e.to_string())?;
        Ok(Self { body, ..req })
    }
}

pub struct HttpResponse {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
    /// `Retry-After` seconds; set automatically on 429 so back-pressured
    /// clients know to pause before resubmitting.
    pub retry_after: Option<u64>,
}

impl HttpResponse {
    pub fn json(status: u16, v: &Value) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: to_string(v),
            // 429 = queue back-pressure, 503 = draining; both mean "this
            // exact request is fine, try again elsewhere/later".
            retry_after: (status == 429 || status == 503).then_some(1),
        }
    }

    pub fn write_to(&self, stream: &mut impl Write) -> std::io::Result<()> {
        write!(
            stream,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
            self.status,
            status_text(self.status),
            self.content_type,
            self.body.len(),
        )?;
        if let Some(secs) = self.retry_after {
            write!(stream, "Retry-After: {secs}\r\n")?;
        }
        write!(stream, "\r\n{}", self.body)
    }
}

pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        429 => "Too Many Requests",
        499 => "Client Closed Request",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub engine: EngineConfig,
    /// Stop after handling this many requests (None = run forever). The
    /// serve_benchmark example uses this for a bounded run.
    pub max_requests: Option<usize>,
}

/// Run the endpoint. Single-threaded accept loop; the engine lives in its
/// worker thread and requests are relayed over an mpsc fan-in so many
/// connections can be in flight (continuous batching inside the worker).
pub fn serve(cfg: ServerConfig) -> Result<(), String> {
    let listener = TcpListener::bind(&cfg.addr).map_err(|e| e.to_string())?;
    log::info!("listening on http://{}", cfg.addr);
    let mut frontend = ServiceWorkerMLCEngine::create(cfg.engine.clone()).map_err(|e| e.to_string())?;
    log::info!("models ready: {:?}", frontend.models());

    // Connection threads parse HTTP and forward messages here; this loop
    // owns the frontend (single consumer of worker msgs).
    let (tx, rx) = channel::<Incoming>();
    let tx_accept = tx.clone();
    let addr = cfg.addr.clone();
    let engine_timeout = cfg.engine.engine_timeout();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = tx_accept.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, tx, engine_timeout);
            });
        }
        let _ = addr;
    });

    let mut handled = 0usize;
    // pending wire-id -> reply channel
    let mut replies: std::collections::HashMap<u64, std::sync::mpsc::Sender<Event>> =
        std::collections::HashMap::new();
    // Drain connections waiting for the worker's Drained announcement.
    let mut drain_acks: Vec<std::sync::mpsc::Sender<Event>> = Vec::new();
    loop {
        // New requests (non-blocking when work is pending).
        while let Ok(incoming) = rx.try_recv() {
            match incoming {
                Incoming::Chat(req, reply) => match frontend.submit(req) {
                    Ok(id) => {
                        replies.insert(id, reply);
                    }
                    Err(e) => {
                        let _ = reply.send(Event::Error(e));
                    }
                },
                Incoming::Drain { timeout_ms, ack } => {
                    match frontend.drain(timeout_ms) {
                        Ok(()) => drain_acks.push(ack),
                        Err(e) => {
                            let _ = ack.send(Event::Error(e));
                        }
                    }
                }
            }
        }
        // Worker events.
        match frontend.poll(Duration::from_millis(20)) {
            Ok(FromWorker::Chunk { id, chunk }) => {
                if let Some(r) = replies.get(&id) {
                    let _ = r.send(Event::Chunk(chunk.to_json()));
                }
            }
            Ok(FromWorker::Done { id, response }) => {
                if let Some(r) = replies.remove(&id) {
                    let _ = r.send(Event::Done(response.to_json()));
                    handled += 1;
                }
            }
            Ok(FromWorker::Error { id, error }) => {
                if let Some(r) = replies.remove(&id) {
                    let _ = r.send(Event::Error(error));
                    handled += 1;
                }
            }
            Ok(FromWorker::Drained) => {
                for ack in drain_acks.drain(..) {
                    let _ = ack.send(Event::Done(crate::obj! {"status" => "drained"}));
                }
            }
            _ => {}
        }
        if let Some(max) = cfg.max_requests {
            if handled >= max && replies.is_empty() {
                return Ok(());
            }
        }
    }
}

/// Connection-thread -> serve-loop messages.
pub(crate) enum Incoming {
    Chat(ChatCompletionRequest, std::sync::mpsc::Sender<Event>),
    /// `POST /admin/drain`: close admission, resolve residents, ack when
    /// the worker announces the drain is complete.
    Drain { timeout_ms: Option<u64>, ack: std::sync::mpsc::Sender<Event> },
}

pub(crate) enum Event {
    Chunk(Value),
    Done(Value),
    Error(ApiError),
}

fn handle_connection(
    stream: TcpStream,
    tx: std::sync::mpsc::Sender<Incoming>,
    engine_timeout: Duration,
) -> Result<(), String> {
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
    let req = HttpRequest::read_from(&mut reader)?;
    let mut out = stream;

    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/chat/completions") => {
            let parsed = crate::json::parse(&req.body)
                .map_err(|e| ApiError::invalid(format!("body: {e}")))
                .and_then(|v| ChatCompletionRequest::from_json(&v));
            let request = match parsed {
                Ok(r) => r,
                Err(e) => {
                    let _ = HttpResponse::json(e.status, &e.to_json()).write_to(&mut out);
                    return Ok(());
                }
            };
            let stream_mode = request.stream;
            let (reply_tx, reply_rx) = channel::<Event>();
            tx.send(Incoming::Chat(request, reply_tx)).map_err(|e| e.to_string())?;

            if stream_mode {
                // The SSE preamble is deferred until the engine produces a
                // first event: a submit-time rejection (429 queue_full,
                // 503 draining, 404, ...) goes out as a plain status +
                // Retry-After instead of buried inside a 200 event stream.
                match reply_rx.recv_timeout(engine_timeout) {
                    Ok(Event::Error(e)) => {
                        let _ = HttpResponse::json(e.status, &e.to_json()).write_to(&mut out);
                    }
                    Err(_) => {
                        let e = ApiError::timeout("engine produced no event within --engine-timeout");
                        let _ = HttpResponse::json(e.status, &e.to_json()).write_to(&mut out);
                    }
                    Ok(first) => {
                        let mut sse =
                            super::sse::SseWriter::start(&mut out).map_err(|e| e.to_string())?;
                        let mut ev = first;
                        loop {
                            match ev {
                                Event::Chunk(v) => {
                                    sse.send_json(&v).map_err(|e| e.to_string())?;
                                }
                                Event::Done(_) => {
                                    sse.done().map_err(|e| e.to_string())?;
                                    break;
                                }
                                Event::Error(e) => {
                                    sse.send_json(&e.to_json()).map_err(|er| er.to_string())?;
                                    break;
                                }
                            }
                            ev = match reply_rx.recv_timeout(engine_timeout) {
                                Ok(ev) => ev,
                                // Surface the stall as a structured SSE
                                // error event, not a silent hangup.
                                Err(_) => Event::Error(ApiError::timeout(
                                    "engine produced no event within --engine-timeout",
                                )),
                            };
                        }
                    }
                }
            } else {
                match reply_rx.recv_timeout(engine_timeout) {
                    Ok(Event::Done(v)) => {
                        let _ = HttpResponse::json(200, &v).write_to(&mut out);
                    }
                    Ok(Event::Error(e)) => {
                        let _ = HttpResponse::json(e.status, &e.to_json()).write_to(&mut out);
                    }
                    Ok(Event::Chunk(_)) => {}
                    Err(_) => {
                        let e = ApiError::timeout("engine produced no event within --engine-timeout");
                        let _ = HttpResponse::json(e.status, &e.to_json()).write_to(&mut out);
                    }
                }
            }
        }
        ("POST", "/admin/drain") => {
            // Optional body: {"timeout_ms": N}. Blocks until the worker
            // announces the drain is complete, then returns the ack.
            let timeout_ms = crate::json::parse(&req.body)
                .ok()
                .and_then(|v| v.get("timeout_ms").and_then(Value::as_u64));
            let (ack_tx, ack_rx) = channel::<Event>();
            tx.send(Incoming::Drain { timeout_ms, ack: ack_tx }).map_err(|e| e.to_string())?;
            match ack_rx.recv_timeout(engine_timeout) {
                Ok(Event::Done(v)) => {
                    let _ = HttpResponse::json(200, &v).write_to(&mut out);
                }
                Ok(Event::Error(e)) => {
                    let _ = HttpResponse::json(e.status, &e.to_json()).write_to(&mut out);
                }
                _ => {
                    let e = ApiError::timeout("drain did not complete within --engine-timeout");
                    let _ = HttpResponse::json(e.status, &e.to_json()).write_to(&mut out);
                }
            }
        }
        ("GET", "/health") => {
            let _ = HttpResponse::json(200, &crate::obj! {"status" => "ok"}).write_to(&mut out);
        }
        ("GET", _) | ("POST", _) => {
            let e = ApiError::not_found(format!("no route {} {}", req.method, req.path));
            let _ = HttpResponse::json(404, &e.to_json()).write_to(&mut out);
        }
        _ => {
            let e = ApiError::invalid("method not allowed");
            let _ = HttpResponse::json(405, &e.to_json()).write_to(&mut out);
        }
    }
    Ok(())
}
