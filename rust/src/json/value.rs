//! JSON value model.
//!
//! Objects preserve insertion order (a `Vec`-backed map): OpenAI-style
//! payloads are small, order-preserving output is friendlier to diff in
//! tests and logs, and lookup cost is irrelevant at these sizes.

use std::fmt;

/// An order-preserving string-keyed map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Map {
    entries: Vec<(String, Value)>,
}

impl Map {
    pub fn new() -> Self {
        Self { entries: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { entries: Vec::with_capacity(n) }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn get_mut(&mut self, key: &str) -> Option<&mut Value> {
        self.entries.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// Insert or replace; replacement keeps the original position.
    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<Value>) {
        let key = key.into();
        let value = value.into();
        if let Some(v) = self.get_mut(&key) {
            *v = value;
        } else {
            self.entries.push((key, value));
        }
    }

    pub fn remove(&mut self, key: &str) -> Option<Value> {
        let idx = self.entries.iter().position(|(k, _)| k == key)?;
        Some(self.entries.remove(idx).1)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.entries.iter().map(|(k, v)| (k, v))
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.iter().map(|(k, _)| k)
    }
}

impl FromIterator<(String, Value)> for Map {
    fn from_iter<T: IntoIterator<Item = (String, Value)>>(iter: T) -> Self {
        let mut m = Map::new();
        for (k, v) in iter {
            m.insert(k, v);
        }
        m
    }
}

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// All JSON numbers; integers round-trip exactly up to 2^53 like JS.
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(Map),
}

impl Value {
    pub fn object() -> Value {
        Value::Object(Map::new())
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Number(n) if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&Vec<Value>> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&Map> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    pub fn as_object_mut(&mut self) -> Option<&mut Map> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// `value.get("a")` object-field access; `Null` propagates nothing.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Array element access.
    pub fn at(&self, idx: usize) -> Option<&Value> {
        self.as_array().and_then(|a| a.get(idx))
    }

    /// Field access with a default when missing or null.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a Value) -> &'a Value {
        match self.get(key) {
            Some(Value::Null) | None => default,
            Some(v) => v,
        }
    }

    pub fn set(&mut self, key: impl Into<String>, value: impl Into<Value>) -> &mut Self {
        if let Value::Object(o) = self {
            o.insert(key, value);
        }
        self
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&super::to_string(self))
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Number(v)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Number(v as f64)
    }
}
impl From<i32> for Value {
    fn from(v: i32) -> Self {
        Value::Number(v as f64)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Number(v as f64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Number(v as f64)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::String(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::String(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}
impl From<Map> for Value {
    fn from(v: Map) -> Self {
        Value::Object(v)
    }
}
impl<T: Into<Value>> From<Option<T>> for Value {
    fn from(v: Option<T>) -> Self {
        match v {
            Some(x) => x.into(),
            None => Value::Null,
        }
    }
}

/// `obj!{"k" => v, ...}` object literal helper.
#[macro_export]
macro_rules! obj {
    ($($k:expr => $v:expr),* $(,)?) => {{
        let mut m = $crate::json::Map::new();
        $( m.insert($k, $v); )*
        $crate::json::Value::Object(m)
    }};
}

/// `arr![a, b, c]` array literal helper.
#[macro_export]
macro_rules! arr {
    ($($v:expr),* $(,)?) => {
        $crate::json::Value::Array(vec![ $( $crate::json::Value::from($v) ),* ])
    };
}
