//! Recursive-descent JSON parser (RFC 8259).
//!
//! Strict: no trailing commas, no comments, proper \uXXXX (incl. surrogate
//! pairs) handling, depth-limited against stack exhaustion from hostile
//! request bodies (the HTTP endpoint feeds user bytes straight in here).

use super::{Map, Value};
use std::fmt;

const MAX_DEPTH: usize = 128;

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub message: String,
    /// Byte offset of the error.
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { message: msg.into(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, ParseError> {
        if depth > MAX_DEPTH {
            return Err(self.err("maximum nesting depth exceeded"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = Map::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid unicode escape"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences from raw bytes.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid UTF-8"))?;
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // fraction
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected after '.'"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // exponent
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}
