//! JSON serialization: compact and pretty writers.
//!
//! Numbers serialize JS-style: integral f64s up to 2^53 print without a
//! decimal point so ids/counts round-trip through the OpenAI-style wire
//! format the way client code expects.

use super::Value;

/// Compact serialization (no whitespace).
pub fn to_string(v: &Value) -> String {
    let mut out = String::with_capacity(128);
    write_value(v, &mut out, None, 0);
    out
}

/// Pretty serialization with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::with_capacity(256);
    write_value(v, &mut out, Some(2), 0);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like JS JSON.stringify.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() <= 2f64.powi(53) {
        out.push_str(&format!("{}", n as i64));
    } else if n != 0.0 && (n.abs() >= 1e21 || n.abs() < 1e-6) {
        // JS-style exponential for extreme magnitudes (Rust's Display
        // would emit hundreds of digits).
        out.push_str(&format!("{n:e}"));
    } else {
        // Shortest roundtrip via Rust's float Display (Ryu-style).
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}
