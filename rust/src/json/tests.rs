use super::*;
use crate::testutil::prop::{PropRng, Runner};
use crate::{arr, obj};

#[test]
fn parse_scalars() {
    assert_eq!(parse("null").unwrap(), Value::Null);
    assert_eq!(parse("true").unwrap(), Value::Bool(true));
    assert_eq!(parse("false").unwrap(), Value::Bool(false));
    assert_eq!(parse("42").unwrap(), Value::Number(42.0));
    assert_eq!(parse("-0.5e2").unwrap(), Value::Number(-50.0));
    assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
}

#[test]
fn parse_structures() {
    let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
    assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b"), Some(&Value::Null));
    assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
}

#[test]
fn parse_escapes_and_unicode() {
    let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
    assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    // surrogate pair: 😀
    let v = parse(r#""😀""#).unwrap();
    assert_eq!(v.as_str(), Some("😀"));
    // raw multibyte passthrough
    let v = parse("\"日本語\"").unwrap();
    assert_eq!(v.as_str(), Some("日本語"));
}

#[test]
fn parse_rejects_garbage() {
    for bad in [
        "", "{", "[1,", "{\"a\":}", "{'a':1}", "[1 2]", "nul", "+1", "01", "1.",
        "\"\\x\"", "\"unterminated", "{\"a\":1,}", "[1,2,]", "\"\\ud800\"",
    ] {
        assert!(parse(bad).is_err(), "should reject {bad:?}");
    }
}

#[test]
fn parse_depth_limit() {
    let deep = "[".repeat(500) + &"]".repeat(500);
    assert!(parse(&deep).is_err());
    let ok = "[".repeat(100) + &"]".repeat(100);
    assert!(parse(&ok).is_ok());
}

#[test]
fn serialize_compact_and_pretty() {
    let v = obj! {
        "model" => "llama-web-80m",
        "n" => 3,
        "stream" => true,
        "stop" => arr!["\n", "###"],
    };
    let s = to_string(&v);
    assert_eq!(
        s,
        "{\"model\":\"llama-web-80m\",\"n\":3,\"stream\":true,\"stop\":[\"\\n\",\"###\"]}"
    );
    let p = to_string_pretty(&v);
    assert!(p.contains("\n  \"model\": \"llama-web-80m\""));
    assert_eq!(parse(&p).unwrap(), v);
}

#[test]
fn numbers_roundtrip_js_style() {
    assert_eq!(to_string(&Value::Number(3.0)), "3");
    assert_eq!(to_string(&Value::Number(-0.25)), "-0.25");
    assert_eq!(to_string(&Value::Number(1e300)), "1e300");
    assert_eq!(to_string(&Value::Number(f64::NAN)), "null");
    let v = parse("9007199254740992").unwrap(); // 2^53
    assert_eq!(v.as_f64(), Some(9007199254740992.0));
}

#[test]
fn map_preserves_insertion_order_and_replaces() {
    let mut m = Map::new();
    m.insert("b", 1);
    m.insert("a", 2);
    m.insert("b", 3);
    let keys: Vec<_> = m.keys().cloned().collect();
    assert_eq!(keys, vec!["b", "a"]);
    assert_eq!(m.get("b").unwrap().as_i64(), Some(3));
}

#[test]
fn accessor_helpers() {
    let v = parse(r#"{"n": 7, "s": "x", "f": 1.5, "b": false, "a": [1]}"#).unwrap();
    assert_eq!(v.get("n").unwrap().as_usize(), Some(7));
    assert_eq!(v.get("f").unwrap().as_i64(), None);
    assert_eq!(v.get_or("missing", &Value::Bool(true)).as_bool(), Some(true));
    assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 1);
}

// -- property tests ---------------------------------------------------------

fn arbitrary_value(rng: &mut PropRng, depth: usize) -> Value {
    match rng.range(if depth > 3 { 4 } else { 6 }) {
        0 => Value::Null,
        1 => Value::Bool(rng.bool()),
        2 => {
            // Mix of integers and floats.
            if rng.bool() {
                Value::Number(rng.i64_in(-1_000_000, 1_000_000) as f64)
            } else {
                Value::Number(f64::from_bits(rng.u64()) % 1e12)
            }
        }
        3 => Value::String(rng.string(24)),
        4 => {
            let n = rng.range(4);
            Value::Array((0..n).map(|_| arbitrary_value(rng, depth + 1)).collect())
        }
        _ => {
            let n = rng.range(4);
            let mut m = Map::new();
            for _ in 0..n {
                m.insert(rng.string(8), arbitrary_value(rng, depth + 1));
            }
            Value::Object(m)
        }
    }
}

#[test]
fn prop_roundtrip_parse_serialize() {
    Runner::new("json_roundtrip", 300).run(|rng| {
        let mut v = arbitrary_value(rng, 0);
        // NaN/Inf intentionally don't roundtrip (serialize to null): skip.
        fn scrub(v: &mut Value) {
            match v {
                Value::Number(n) if !n.is_finite() => *v = Value::Null,
                Value::Array(a) => a.iter_mut().for_each(scrub),
                Value::Object(o) => {
                    let keys: Vec<String> = o.keys().cloned().collect();
                    for k in keys {
                        scrub(o.get_mut(&k).unwrap());
                    }
                }
                _ => {}
            }
        }
        scrub(&mut v);
        let s = to_string(&v);
        let back = parse(&s).map_err(|e| format!("{e}: {s}"))?;
        if back != v {
            return Err(format!("roundtrip mismatch: {v:?} -> {s} -> {back:?}"));
        }
        // pretty form parses to the same value
        let back2 = parse(&to_string_pretty(&v)).map_err(|e| e.to_string())?;
        if back2 != v {
            return Err("pretty roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_parser_never_panics_on_noise() {
    Runner::new("json_fuzz", 500).run(|rng| {
        let len = rng.range(64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.u64() as u8).collect();
        if let Ok(s) = std::str::from_utf8(&bytes) {
            let _ = parse(s); // must not panic
        }
        Ok(())
    });
}
