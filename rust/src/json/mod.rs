//! Minimal-dependency JSON substrate.
//!
//! WebLLM's user-facing contract is "endpoint-like, JSON-in-JSON-out"
//! (paper §2.1); the worker boundary also carries JSON messages (§2.2).
//! The vendored crate set has no serde, so this module owns the JSON
//! value model, parser, and serializer used by the OpenAI-style API
//! (`crate::api`), the wire protocol (`crate::coordinator::messages`),
//! the grammar engine's JSON-Schema compiler, and artifact manifests.

mod parse;
mod ser;
mod value;

pub use parse::{parse, ParseError};
pub use ser::{to_string, to_string_pretty};
pub use value::{Map, Value};

#[cfg(test)]
mod tests;
