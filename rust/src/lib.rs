//! # WebLLM reproduction — in-browser LLM inference engine, rebuilt as a
//! # Rust + JAX + Pallas three-layer stack
//!
//! Reproduction of *WebLLM: A High-Performance In-Browser LLM Inference
//! Engine* (Ruan et al., 2024). The paper's browser engine maps onto:
//!
//! * **L3 (this crate)** — the coordination system: `coordinator` holds
//!   the `MLCEngine` (worker-side backend) and `ServiceWorkerMLCEngine`
//!   (frontend handle over a JSON message channel), the continuous-
//!   batching scheduler, streaming, and multi-model routing. Substrates:
//!   `json`, `api` (OpenAI-style types), `tokenizer` (byte-level BPE),
//!   `sampler`, `grammar` (structured generation), `kvcache` (paged KV
//!   metadata), `http` (endpoint + SSE), `browser` (browser-environment
//!   cost model), `metrics`.
//! * **L2/L1 (build-time Python)** — the model graph and Pallas kernels,
//!   AOT-lowered to HLO text artifacts that `runtime` loads and executes
//!   through the PJRT CPU client (`xla` crate). Python is never on the
//!   request path.
//!
//! See DESIGN.md for the full inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod api;
pub mod browser;
pub mod coordinator;
pub mod grammar;
pub mod http;
pub mod json;
pub mod kvcache;
pub mod lru;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod sampler;
pub mod tokenizer;

pub mod testutil;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Repo-root-relative artifacts directory (override with WEBLLM_ARTIFACTS).
pub fn artifacts_dir() -> std::path::PathBuf {
    match std::env::var("WEBLLM_ARTIFACTS") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    }
}
