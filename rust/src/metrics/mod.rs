//! Engine metrics: timers, streaming stats, percentile histograms.
//!
//! Used by the benches (rust/benches/) for the Table-1 harness and by the
//! engine's usage/telemetry accounting (`runtime_stats_text` in WebLLM's
//! API). No external deps; percentile queries sort on demand.

use std::time::{Duration, Instant};

/// Running mean/variance (Welford) + min/max.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Sample reservoir with percentile queries (stores everything; bench
/// scales here are thousands of points, not millions).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// p in [0, 100]; nearest-rank.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.samples.len() as f64 - 1.0)).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }
}

/// Wall-clock scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Decode/prefill throughput accounting for one engine run — the numbers
/// behind Table 1 and the serve example's report.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub prefill_time_s: f64,
    pub decode_time_s: f64,
    /// Prefill padding waste: slots in the compiled chunk beyond the
    /// chunk's valid tokens, summed over all prefill chunk executions.
    pub prefill_padded_tokens: u64,
    /// Positioned prefill chunk executions (>= 1 per admitted request;
    /// > 1 when a prompt outruns the per-step chunk budget).
    pub prefill_chunks: u64,
    /// Leading prompt tokens whose prefill compute was skipped because a
    /// prefix-cache hit left them resident in reused pages.
    pub prefill_cached_tokens_skipped: u64,
    /// Wall-clock the decode batch spent stalled behind a prefill chunk
    /// (chunk exec time accrued while >= 1 sequence was decoding) — the
    /// interference the chunk budget exists to bound.
    pub decode_stall_s: f64,
    /// Prefill chunks that ran while >= 1 sequence was decoding.
    pub decode_stall_chunks: u64,
    /// Batched decode steps executed.
    pub decode_steps: u64,
    /// Rows in decode batches that carried a live sequence.
    pub decode_live_rows: u64,
    /// Rows in decode batches that were static-shape padding (the compiled
    /// batch size exceeded the number of running sequences).
    pub decode_padded_rows: u64,
    /// Grammar compilations run at admission (one per distinct grammar;
    /// later requests share the `CompiledGrammar`).
    pub grammar_compiles: u64,
    /// Wall-clock spent in those compilations (the one-shot AOT cost the
    /// per-state residue savings amortize).
    pub grammar_compile_s: f64,
    /// Tokens classified always-accepted at compile time, summed over
    /// compilations.
    pub grammar_base_accept_tokens: u64,
    /// Tokens classified always-rejected at compile time, summed over
    /// compilations.
    pub grammar_base_reject_tokens: u64,
    /// Context-dependent tokens left for the per-state runtime walk,
    /// summed over compilations.
    pub grammar_residue_tokens: u64,
    /// Mask-cache lookups answered by a cached mask (`Rc` clone).
    pub grammar_mask_hits: u64,
    /// Mask-cache lookups that paid a residue trie walk.
    pub grammar_mask_misses: u64,
    /// Mask-cache entries evicted by the LRU capacity bound.
    pub grammar_mask_evictions: u64,
    /// Tokens emitted by grammar fast-forward — appended because the
    /// grammar forced them, with zero model and zero sampler calls.
    pub ff_tokens: u64,
    /// Tokens proposed by the draft model across all speculation rounds.
    pub draft_proposed: u64,
    /// Draft proposals confirmed by target verification (emitted without
    /// their own target decode step).
    pub draft_accepted: u64,
    /// Speculative verify calls (draft-propose + target-verify rounds).
    pub spec_steps: u64,
    /// Sequences whose KV pages were evicted under memory pressure (they
    /// re-enter the prefill path and recompute on resume).
    pub preemptions: u64,
    /// Tokens recomputed through `prefill_chunk` because a preempted
    /// sequence resumed past its surviving prefix-cache boundary — the
    /// price paid for recompute-on-resume (spill/restore would zero it).
    pub preempted_tokens_recomputed: u64,
    /// KV forks: each `n>1` fan-out branch (beyond the first) that
    /// shared its parent's pages instead of re-prefilling the prompt.
    pub forks: u64,
    /// Physical page copies applied for fork tails and copy-on-write
    /// un-shares (backends with a page-copy primitive; the recompute
    /// fallback shows up in `prefill_chunks` instead).
    pub cow_page_copies: u64,
    /// Peak number of pool pages simultaneously shared (refcount > 1)
    /// by forked families and live prefix hits. A high-water gauge.
    pub shared_pages: u64,
    /// Backend faults the engine observed (transient errors, device
    /// losses, non-finite logit rows) — injected or real.
    pub faults_injected: u64,
    /// In-place retries of transiently-failed backend ops (bounded; an
    /// exhausted budget escalates to a device reset).
    pub transient_retries: u64,
    /// Device-loss recoveries: `reset_cache` + preempt-all + recompute
    /// on resume.
    pub device_resets: u64,
    /// Scheduler steps that completed but overran the stuck-step
    /// watchdog threshold.
    pub watchdog_stalls: u64,
    /// Requests failed individually by a data-plane fault or an engine
    /// invariant breach (exactly one per fault — never the fleet).
    pub requests_failed: u64,
    /// Requests failed by their deadline (`deadline_ms` /
    /// `--request-timeout`).
    pub requests_timed_out: u64,
    /// Submissions rejected with 503 because the engine was draining.
    pub drain_rejected: u64,
    /// Resident requests that finished normally during a drain.
    pub drain_completed: u64,
    /// Resident requests failed because the drain deadline passed.
    pub drain_failed: u64,
    /// Time from request admission to first streamed token.
    pub ttft: Histogram,
    /// Inter-token latency.
    pub itl: Histogram,
    /// End-to-end request latency (admission to completion).
    pub e2e: Histogram,
}

impl EngineStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn prefill_tps(&self) -> f64 {
        if self.prefill_time_s == 0.0 {
            0.0
        } else {
            self.prefill_tokens as f64 / self.prefill_time_s
        }
    }

    pub fn decode_tps(&self) -> f64 {
        if self.decode_time_s == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.decode_time_s
        }
    }

    /// Fraction of decode-batch rows wasted on static-shape padding
    /// (0.0 when no decode step has run).
    pub fn decode_padding_ratio(&self) -> f64 {
        let total = self.decode_live_rows + self.decode_padded_rows;
        if total == 0 {
            0.0
        } else {
            self.decode_padded_rows as f64 / total as f64
        }
    }

    /// Fraction of draft proposals the target confirmed (0.0 before any
    /// speculation).
    pub fn draft_accept_rate(&self) -> f64 {
        if self.draft_proposed == 0 {
            0.0
        } else {
            self.draft_accepted as f64 / self.draft_proposed as f64
        }
    }

    /// Grammar mask-cache hit rate (0.0 before any constrained decode).
    pub fn grammar_mask_hit_rate(&self) -> f64 {
        let total = self.grammar_mask_hits + self.grammar_mask_misses;
        if total == 0 {
            0.0
        } else {
            self.grammar_mask_hits as f64 / total as f64
        }
    }

    /// Fraction of compiled vocabulary entries classified ahead of time
    /// (context-independent), averaged over all compilations.
    pub fn grammar_context_independent_fraction(&self) -> f64 {
        let ci = self.grammar_base_accept_tokens + self.grammar_base_reject_tokens;
        let total = ci + self.grammar_residue_tokens;
        if total == 0 {
            0.0
        } else {
            ci as f64 / total as f64
        }
    }

    /// The engine-level numbers as a JSON object (the scalar core of the
    /// engine's `runtime_stats_text` analog; the engine wraps this with
    /// per-model state).
    pub fn stats_json(&self) -> crate::json::Value {
        crate::obj! {
            "prefill_tokens" => self.prefill_tokens as i64,
            "decode_tokens" => self.decode_tokens as i64,
            "prefill_tps" => self.prefill_tps(),
            "decode_tps" => self.decode_tps(),
            "prefill_padded_tokens" => self.prefill_padded_tokens as i64,
            "prefill_chunks" => self.prefill_chunks as i64,
            "prefill_cached_tokens_skipped" => self.prefill_cached_tokens_skipped as i64,
            "decode_stall_s" => self.decode_stall_s,
            "decode_stall_chunks" => self.decode_stall_chunks as i64,
            "decode_steps" => self.decode_steps as i64,
            "decode_live_rows" => self.decode_live_rows as i64,
            "decode_padded_rows" => self.decode_padded_rows as i64,
            "decode_padding_ratio" => self.decode_padding_ratio(),
            "preemptions" => self.preemptions as i64,
            "preempted_tokens_recomputed" => self.preempted_tokens_recomputed as i64,
            "forks" => self.forks as i64,
            "cow_page_copies" => self.cow_page_copies as i64,
            "shared_pages" => self.shared_pages as i64,
            "e2e_requests" => self.e2e.len() as i64,
            "e2e_mean_s" => self.e2e.mean(),
            "speculative" => crate::obj! {
                "ff_tokens" => self.ff_tokens as i64,
                "draft_proposed" => self.draft_proposed as i64,
                "draft_accepted" => self.draft_accepted as i64,
                "draft_accept_rate" => self.draft_accept_rate(),
                "spec_steps" => self.spec_steps as i64,
            },
            "faults" => crate::obj! {
                "faults_injected" => self.faults_injected as i64,
                "transient_retries" => self.transient_retries as i64,
                "device_resets" => self.device_resets as i64,
                "watchdog_stalls" => self.watchdog_stalls as i64,
                "requests_failed" => self.requests_failed as i64,
                "requests_timed_out" => self.requests_timed_out as i64,
                "drain_rejected" => self.drain_rejected as i64,
                "drain_completed" => self.drain_completed as i64,
                "drain_failed" => self.drain_failed as i64,
            },
            "grammar" => crate::obj! {
                "compiles" => self.grammar_compiles as i64,
                "compile_s" => self.grammar_compile_s,
                "base_accept_tokens" => self.grammar_base_accept_tokens as i64,
                "base_reject_tokens" => self.grammar_base_reject_tokens as i64,
                "residue_tokens" => self.grammar_residue_tokens as i64,
                "context_independent_fraction" => self.grammar_context_independent_fraction(),
                "mask_hits" => self.grammar_mask_hits as i64,
                "mask_misses" => self.grammar_mask_misses as i64,
                "mask_evictions" => self.grammar_mask_evictions as i64,
                "mask_hit_rate" => self.grammar_mask_hit_rate(),
            },
        }
    }

    pub fn merge(&mut self, other: &EngineStats) {
        self.prefill_tokens += other.prefill_tokens;
        self.decode_tokens += other.decode_tokens;
        self.prefill_time_s += other.prefill_time_s;
        self.decode_time_s += other.decode_time_s;
        self.prefill_padded_tokens += other.prefill_padded_tokens;
        self.prefill_chunks += other.prefill_chunks;
        self.prefill_cached_tokens_skipped += other.prefill_cached_tokens_skipped;
        self.decode_stall_s += other.decode_stall_s;
        self.decode_stall_chunks += other.decode_stall_chunks;
        self.decode_steps += other.decode_steps;
        self.decode_live_rows += other.decode_live_rows;
        self.decode_padded_rows += other.decode_padded_rows;
        self.grammar_compiles += other.grammar_compiles;
        self.grammar_compile_s += other.grammar_compile_s;
        self.grammar_base_accept_tokens += other.grammar_base_accept_tokens;
        self.grammar_base_reject_tokens += other.grammar_base_reject_tokens;
        self.grammar_residue_tokens += other.grammar_residue_tokens;
        self.grammar_mask_hits += other.grammar_mask_hits;
        self.grammar_mask_misses += other.grammar_mask_misses;
        self.grammar_mask_evictions += other.grammar_mask_evictions;
        self.ff_tokens += other.ff_tokens;
        self.draft_proposed += other.draft_proposed;
        self.draft_accepted += other.draft_accepted;
        self.spec_steps += other.spec_steps;
        self.preemptions += other.preemptions;
        self.preempted_tokens_recomputed += other.preempted_tokens_recomputed;
        self.forks += other.forks;
        self.cow_page_copies += other.cow_page_copies;
        // High-water gauge, not a flow: peak of the peaks.
        self.shared_pages = self.shared_pages.max(other.shared_pages);
        self.faults_injected += other.faults_injected;
        self.transient_retries += other.transient_retries;
        self.device_resets += other.device_resets;
        self.watchdog_stalls += other.watchdog_stalls;
        self.requests_failed += other.requests_failed;
        self.requests_timed_out += other.requests_timed_out;
        self.drain_rejected += other.drain_rejected;
        self.drain_completed += other.drain_completed;
        self.drain_failed += other.drain_failed;
        for &s in &other.ttft.samples {
            self.ttft.push(s);
        }
        for &s in &other.itl.samples {
            self.itl.push(s);
        }
        for &s in &other.e2e.samples {
            self.e2e.push(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-9);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.push(i as f64);
        }
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(50.0), 51.0); // nearest-rank on 0..99
        assert_eq!(h.percentile(100.0), 100.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let mut h = Histogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn engine_stats_throughput_and_merge() {
        let mut a = EngineStats::new();
        a.decode_tokens = 100;
        a.decode_time_s = 2.0;
        a.ttft.push(0.1);
        a.e2e.push(1.5);
        let mut b = EngineStats::new();
        b.decode_tokens = 50;
        b.decode_time_s = 1.0;
        b.ttft.push(0.3);
        a.merge(&b);
        assert_eq!(a.decode_tokens, 150);
        assert!((a.decode_tps() - 50.0).abs() < 1e-9);
        assert_eq!(a.ttft.len(), 2);
        assert_eq!(a.e2e.len(), 1);
    }

    #[test]
    fn engine_stats_padding_accounting() {
        let mut s = EngineStats::new();
        assert_eq!(s.decode_padding_ratio(), 0.0);
        // Two steps at compiled batch 4: one with 3 live rows, one with 1.
        s.decode_steps += 1;
        s.decode_live_rows += 3;
        s.decode_padded_rows += 1;
        s.decode_steps += 1;
        s.decode_live_rows += 1;
        s.decode_padded_rows += 3;
        assert_eq!(s.decode_steps, 2);
        assert!((s.decode_padding_ratio() - 0.5).abs() < 1e-12);
        let mut other = EngineStats::new();
        other.decode_padded_rows = 4;
        other.decode_live_rows = 0;
        other.prefill_padded_tokens = 7;
        s.merge(&other);
        assert_eq!(s.decode_padded_rows, 8);
        assert_eq!(s.prefill_padded_tokens, 7);
    }

    #[test]
    fn engine_stats_chunked_prefill_counters_and_json() {
        let mut s = EngineStats::new();
        s.prefill_chunks = 5;
        s.prefill_cached_tokens_skipped = 24;
        s.decode_stall_chunks = 3;
        s.decode_stall_s = 0.25;

        let v = s.stats_json();
        assert_eq!(v.get("prefill_chunks").and_then(|x| x.as_i64()), Some(5));
        assert_eq!(
            v.get("prefill_cached_tokens_skipped").and_then(|x| x.as_i64()),
            Some(24)
        );
        assert_eq!(v.get("decode_stall_chunks").and_then(|x| x.as_i64()), Some(3));
        assert!((v.get("decode_stall_s").and_then(|x| x.as_f64()).unwrap() - 0.25).abs() < 1e-12);

        let mut other = EngineStats::new();
        other.prefill_chunks = 2;
        other.prefill_cached_tokens_skipped = 8;
        other.decode_stall_chunks = 1;
        other.decode_stall_s = 0.5;
        s.merge(&other);
        assert_eq!(s.prefill_chunks, 7);
        assert_eq!(s.prefill_cached_tokens_skipped, 32);
        assert_eq!(s.decode_stall_chunks, 4);
        assert!((s.decode_stall_s - 0.75).abs() < 1e-12);
    }

    #[test]
    fn engine_stats_preemption_counters_and_json() {
        let mut s = EngineStats::new();
        s.preemptions = 3;
        s.preempted_tokens_recomputed = 120;

        let v = s.stats_json();
        assert_eq!(v.get("preemptions").and_then(|x| x.as_i64()), Some(3));
        assert_eq!(
            v.get("preempted_tokens_recomputed").and_then(|x| x.as_i64()),
            Some(120)
        );

        let mut other = EngineStats::new();
        other.preemptions = 1;
        other.preempted_tokens_recomputed = 16;
        s.merge(&other);
        assert_eq!(s.preemptions, 4);
        assert_eq!(s.preempted_tokens_recomputed, 136);
    }

    #[test]
    fn engine_stats_fork_counters_and_json() {
        let mut s = EngineStats::new();
        s.forks = 3;
        s.cow_page_copies = 5;
        s.shared_pages = 12;

        let v = s.stats_json();
        assert_eq!(v.get("forks").and_then(|x| x.as_i64()), Some(3));
        assert_eq!(v.get("cow_page_copies").and_then(|x| x.as_i64()), Some(5));
        assert_eq!(v.get("shared_pages").and_then(|x| x.as_i64()), Some(12));

        let mut other = EngineStats::new();
        other.forks = 1;
        other.cow_page_copies = 2;
        other.shared_pages = 7; // below s's peak: max wins, not sum
        s.merge(&other);
        assert_eq!(s.forks, 4);
        assert_eq!(s.cow_page_copies, 7);
        assert_eq!(s.shared_pages, 12);
    }

    #[test]
    fn engine_stats_grammar_counters_and_json() {
        let mut s = EngineStats::new();
        assert_eq!(s.grammar_mask_hit_rate(), 0.0);
        assert_eq!(s.grammar_context_independent_fraction(), 0.0);
        s.grammar_compiles = 2;
        s.grammar_base_accept_tokens = 10;
        s.grammar_base_reject_tokens = 60;
        s.grammar_residue_tokens = 30;
        s.grammar_mask_hits = 9;
        s.grammar_mask_misses = 1;
        s.grammar_mask_evictions = 4;
        assert!((s.grammar_mask_hit_rate() - 0.9).abs() < 1e-12);
        assert!((s.grammar_context_independent_fraction() - 0.7).abs() < 1e-12);

        let v = s.stats_json();
        let g = v.get("grammar").expect("grammar section");
        assert_eq!(g.get("compiles").and_then(|x| x.as_i64()), Some(2));
        assert_eq!(g.get("mask_evictions").and_then(|x| x.as_i64()), Some(4));
        assert_eq!(g.get("residue_tokens").and_then(|x| x.as_i64()), Some(30));

        let mut other = EngineStats::new();
        other.grammar_mask_hits = 1;
        other.grammar_mask_evictions = 2;
        other.grammar_compile_s = 0.5;
        s.merge(&other);
        assert_eq!(s.grammar_mask_hits, 10);
        assert_eq!(s.grammar_mask_evictions, 6);
        assert!((s.grammar_compile_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn engine_stats_speculative_counters_and_json() {
        let mut s = EngineStats::new();
        assert_eq!(s.draft_accept_rate(), 0.0);
        s.ff_tokens = 12;
        s.draft_proposed = 8;
        s.draft_accepted = 6;
        s.spec_steps = 3;
        assert!((s.draft_accept_rate() - 0.75).abs() < 1e-12);

        let v = s.stats_json();
        let sp = v.get("speculative").expect("speculative section");
        assert_eq!(sp.get("ff_tokens").and_then(|x| x.as_i64()), Some(12));
        assert_eq!(sp.get("draft_proposed").and_then(|x| x.as_i64()), Some(8));
        assert_eq!(sp.get("draft_accepted").and_then(|x| x.as_i64()), Some(6));
        assert_eq!(sp.get("spec_steps").and_then(|x| x.as_i64()), Some(3));
        let rate = sp.get("draft_accept_rate").and_then(|x| x.as_f64()).unwrap();
        assert!((rate - 0.75).abs() < 1e-12);

        let mut other = EngineStats::new();
        other.ff_tokens = 3;
        other.draft_proposed = 4;
        other.draft_accepted = 1;
        other.spec_steps = 2;
        s.merge(&other);
        assert_eq!(s.ff_tokens, 15);
        assert_eq!(s.draft_proposed, 12);
        assert_eq!(s.draft_accepted, 7);
        assert_eq!(s.spec_steps, 5);
    }

    #[test]
    fn engine_stats_fault_counters_and_json() {
        let mut s = EngineStats::new();
        s.faults_injected = 5;
        s.transient_retries = 3;
        s.device_resets = 1;
        s.watchdog_stalls = 2;
        s.requests_failed = 1;
        s.requests_timed_out = 4;
        s.drain_rejected = 6;
        s.drain_completed = 7;
        s.drain_failed = 1;

        let v = s.stats_json();
        let f = v.get("faults").expect("faults section");
        assert_eq!(f.get("faults_injected").and_then(|x| x.as_i64()), Some(5));
        assert_eq!(f.get("transient_retries").and_then(|x| x.as_i64()), Some(3));
        assert_eq!(f.get("device_resets").and_then(|x| x.as_i64()), Some(1));
        assert_eq!(f.get("watchdog_stalls").and_then(|x| x.as_i64()), Some(2));
        assert_eq!(f.get("requests_failed").and_then(|x| x.as_i64()), Some(1));
        assert_eq!(f.get("requests_timed_out").and_then(|x| x.as_i64()), Some(4));
        assert_eq!(f.get("drain_rejected").and_then(|x| x.as_i64()), Some(6));
        assert_eq!(f.get("drain_completed").and_then(|x| x.as_i64()), Some(7));
        assert_eq!(f.get("drain_failed").and_then(|x| x.as_i64()), Some(1));

        let mut other = EngineStats::new();
        other.faults_injected = 2;
        other.transient_retries = 1;
        other.device_resets = 1;
        other.requests_failed = 3;
        other.drain_completed = 2;
        s.merge(&other);
        assert_eq!(s.faults_injected, 7);
        assert_eq!(s.transient_retries, 4);
        assert_eq!(s.device_resets, 2);
        assert_eq!(s.requests_failed, 4);
        assert_eq!(s.drain_completed, 9);
        assert_eq!(s.watchdog_stalls, 2);
    }
}
