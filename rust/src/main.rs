//! `webllm` CLI — launcher for the reproduction.
//!
//! ```text
//! webllm serve   --model tiny-2m [--addr 127.0.0.1:8080] [--browser]
//! webllm chat    --model tiny-2m [--browser] [--max-tokens N]
//! webllm generate --model tiny-2m --prompt "..." [--json] [--seed S]
//! webllm models
//! webllm stats   --model tiny-2m
//! ```
//!
//! Hand-rolled arg parsing (no clap in the vendored set).

use std::collections::HashMap;
use webllm::api::{ChatCompletionRequest, ResponseFormat};
use webllm::coordinator::{EngineConfig, ServiceWorkerMLCEngine};
use webllm::http::{serve, ServerConfig};
use webllm::tokenizer::Role;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().map(String::as_str) else {
        usage();
        std::process::exit(2);
    };
    let flags = parse_flags(&args[1..]);
    let result = match cmd {
        "serve" => cmd_serve(&flags),
        "chat" => cmd_chat(&flags),
        "generate" => cmd_generate(&flags),
        "models" => cmd_models(),
        "stats" => cmd_stats(&flags),
        "--help" | "-h" | "help" => {
            usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "webllm {} — in-browser LLM inference engine reproduction

USAGE:
  webllm serve    --model <name>[,<name>...] [--addr HOST:PORT] [--browser]
  webllm chat     --model <name> [--browser] [--max-tokens N] [--temperature T]
  webllm generate --model <name> --prompt TEXT [--json] [--max-tokens N] [--seed S] [--n K]
  webllm models
  webllm stats    --model <name>

FLAGS:
  --browser         run in browser mode (inject WebGPU/WASM cost model)
  --reference       run on the deterministic reference backend (no
                    artifacts; models: tiny-ref, tiny-ref-b)
  --artifacts       artifacts directory (default: ./artifacts)
  --prefill-budget  chunked-prefill tokens per scheduler step (clamped to
                    the model's compiled chunk menu; small = smoother
                    streaming under load, large = faster first token)
  --draft-model     speculative decoding: cheaper model that proposes
                    tokens for every loaded target to verify in one
                    batched call (same tokenizer/vocab required)
  --spec-tokens     draft proposals per speculation round (default 4;
                    the cap when the adaptive policy is active)
  --no-adaptive-spec
                    propose a fixed --spec-tokens run every round instead
                    of scaling it to the request's acceptance rate
  --n               parallel completions per generate request (prompt
                    prefilled once, KV forked per choice; default 1)
  --no-fast-forward disable grammar fast-forward (emit grammar-forced
                    token runs without model calls; on by default)
  --priority        scheduling class for chat/generate requests (integer,
                    default 0; higher = admitted first, preempted last)
  --max-concurrent-prefills
                    prompts prefilling at once per model (default 4)
  --max-waiting     waiting-queue cap per model before submit returns 429
                    (default 256)
  --no-adaptive-prefill
                    fixed per-step prefill budget instead of shrinking it
                    as the decode batch grows
  --request-timeout default per-request deadline in seconds (overridden
                    per request by 'deadline_ms'; expired requests fail
                    with a structured timeout_error; default: none)
  --engine-timeout  seconds any channel wait on the engine may block —
                    worker readiness, HTTP replies, SSE gaps (default 600)",
        webllm::version()
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = args.get(i + 1).filter(|v| !v.starts_with("--"));
            match val {
                Some(v) => {
                    flags.insert(key.to_string(), v.clone());
                    i += 2;
                }
                None => {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    flags
}

fn engine_config(flags: &HashMap<String, String>) -> Result<EngineConfig, String> {
    let models: Vec<&str> = flags
        .get("model")
        .map(|m| m.split(',').collect())
        .ok_or("--model is required")?;
    let mut cfg = match (flags.contains_key("reference"), flags.contains_key("browser")) {
        (true, true) => EngineConfig::reference_browser(&models),
        (true, false) => EngineConfig::reference(&models),
        (false, true) => EngineConfig::browser(&models),
        (false, false) => EngineConfig::native(&models),
    };
    if let Some(dir) = flags.get("artifacts") {
        if flags.contains_key("reference") {
            eprintln!("warning: --artifacts is ignored with --reference (in-code registry)");
        } else {
            cfg.artifacts_dir = dir.into();
        }
    }
    if let Some(b) = flags.get("prefill-budget") {
        cfg.prefill_token_budget = b
            .parse()
            .map_err(|_| format!("--prefill-budget: '{b}' is not a token count"))?;
    }
    if let Some(d) = flags.get("draft-model") {
        cfg.draft_model = Some(d.clone());
    }
    if let Some(k) = flags.get("spec-tokens") {
        cfg.spec_tokens = k
            .parse()
            .map_err(|_| format!("--spec-tokens: '{k}' is not a token count"))?;
    }
    if flags.contains_key("no-fast-forward") {
        cfg.enable_fast_forward = false;
    }
    if flags.contains_key("no-adaptive-spec") {
        cfg.adaptive_spec_tokens = false;
    }
    if let Some(n) = flags.get("max-concurrent-prefills") {
        cfg.max_concurrent_prefills = n
            .parse()
            .map_err(|_| format!("--max-concurrent-prefills: '{n}' is not a count"))?;
    }
    if let Some(n) = flags.get("max-waiting") {
        cfg.max_waiting_requests =
            n.parse().map_err(|_| format!("--max-waiting: '{n}' is not a count"))?;
    }
    if flags.contains_key("no-adaptive-prefill") {
        cfg.adaptive_prefill = false;
    }
    if let Some(s) = flags.get("request-timeout") {
        let secs: u64 =
            s.parse().map_err(|_| format!("--request-timeout: '{s}' is not seconds"))?;
        cfg.request_timeout_ms = Some(secs.saturating_mul(1000));
    }
    if let Some(s) = flags.get("engine-timeout") {
        let secs: u64 =
            s.parse().map_err(|_| format!("--engine-timeout: '{s}' is not seconds"))?;
        cfg.engine_timeout_ms = secs.saturating_mul(1000);
    }
    Ok(cfg)
}

fn priority_flag(flags: &HashMap<String, String>) -> Result<i32, String> {
    match flags.get("priority") {
        None => Ok(0),
        Some(p) => p.parse().map_err(|_| format!("--priority: '{p}' is not an integer")),
    }
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<(), String> {
    let cfg = ServerConfig {
        addr: flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:8080".into()),
        engine: engine_config(flags)?,
        max_requests: flags.get("max-requests").and_then(|v| v.parse().ok()),
    };
    eprintln!("loading models {:?} ...", cfg.engine.models);
    serve(cfg)
}

fn cmd_chat(flags: &HashMap<String, String>) -> Result<(), String> {
    let cfg = engine_config(flags)?;
    let model = cfg.models[0].clone();
    eprintln!("loading {model} ...");
    let mut fe = ServiceWorkerMLCEngine::create(cfg).map_err(|e| e.to_string())?;
    eprintln!("ready. type a message; 'exit' quits.");
    let max_tokens: usize = flags.get("max-tokens").and_then(|v| v.parse().ok()).unwrap_or(64);
    let temperature: f32 = flags.get("temperature").and_then(|v| v.parse().ok()).unwrap_or(0.7);

    let mut history: Vec<(Role, String)> = Vec::new();
    let stdin = std::io::stdin();
    loop {
        eprint!("> ");
        let mut line = String::new();
        if stdin.read_line(&mut line).map_err(|e| e.to_string())? == 0 {
            return Ok(());
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "exit" {
            return Ok(());
        }
        history.push((Role::User, line.to_string()));
        let mut req = ChatCompletionRequest::new(&model);
        for (role, content) in &history {
            req = req.message(*role, content.clone());
        }
        req.max_tokens = max_tokens;
        req.sampling.temperature = temperature;
        req.priority = priority_flag(flags)?;
        let resp = fe
            .chat_completion_stream(req, |c| {
                print!("{}", c.delta);
                use std::io::Write;
                let _ = std::io::stdout().flush();
            })
            .map_err(|e| e.to_string())?;
        println!();
        eprintln!(
            "[{} tok, {:.1} tok/s]",
            resp.usage.completion_tokens, resp.usage.decode_tokens_per_s
        );
        history.push((Role::Assistant, resp.text().to_string()));
    }
}

fn cmd_generate(flags: &HashMap<String, String>) -> Result<(), String> {
    let cfg = engine_config(flags)?;
    let model = cfg.models[0].clone();
    let prompt = flags.get("prompt").ok_or("--prompt is required")?.clone();
    let mut fe = ServiceWorkerMLCEngine::create(cfg).map_err(|e| e.to_string())?;
    let mut req = ChatCompletionRequest::new(&model).user(prompt);
    req.max_tokens = flags.get("max-tokens").and_then(|v| v.parse().ok()).unwrap_or(64);
    req.sampling.seed = flags.get("seed").and_then(|v| v.parse().ok());
    req.priority = priority_flag(flags)?;
    if let Some(n) = flags.get("n") {
        req.n = n.parse().map_err(|_| format!("--n: '{n}' is not a count"))?;
    }
    if flags.contains_key("json") {
        req.response_format = ResponseFormat::JsonObject;
    }
    let resp = fe.chat_completion(req).map_err(|e| e.to_string())?;
    if resp.choices.len() == 1 {
        println!("{}", resp.text());
    } else {
        for c in &resp.choices {
            println!("--- choice {} [{}]", c.index, c.finish_reason.as_str());
            println!("{}", c.content);
        }
    }
    eprintln!(
        "[prompt {} tok | completion {} tok | ttft {:.3}s | {:.1} tok/s]",
        resp.usage.prompt_tokens,
        resp.usage.completion_tokens,
        resp.usage.ttft_s,
        resp.usage.decode_tokens_per_s
    );
    Ok(())
}

fn cmd_models() -> Result<(), String> {
    let manifest = webllm::models::Manifest::load(&webllm::artifacts_dir())?;
    println!(
        "{:<16} {:>10} {:>8} {:>8} {:>10} {:>12}",
        "MODEL", "PARAMS", "LAYERS", "HEADS", "MAX_SEQ", "BATCHES"
    );
    for (name, rec) in &manifest.models {
        let c = &rec.config;
        println!(
            "{:<16} {:>10} {:>8} {:>8} {:>10} {:>12}",
            name,
            c.param_count,
            c.n_layers,
            format!("{}/{}", c.n_heads, c.n_kv_heads),
            c.max_seq_len,
            format!("{:?}", c.decode_batches),
        );
    }
    Ok(())
}

fn cmd_stats(flags: &HashMap<String, String>) -> Result<(), String> {
    let cfg = engine_config(flags)?;
    let mut fe = ServiceWorkerMLCEngine::create(cfg).map_err(|e| e.to_string())?;
    let mut req = ChatCompletionRequest::new(&fe.models()[0].clone()).user("warmup request");
    req.max_tokens = 16;
    fe.chat_completion(req).map_err(|e| e.to_string())?;
    let stats = fe.stats().map_err(|e| e.to_string())?;
    println!("{}", webllm::json::to_string_pretty(&stats));
    Ok(())
}
