//! PCG-XSH-RR 64/32: the engine's sampling RNG.
//!
//! Deterministic per request when `seed` is set (OpenAI API semantics);
//! otherwise seeded from the request id + a process nonce.

/// PCG-XSH-RR 64/32 generator: 64-bit state, 32-bit output. Cloning
/// forks the stream (both copies then produce identical draws).
///
/// ```
/// use webllm::sampler::Pcg32;
///
/// let mut a = Pcg32::new(7);
/// let mut b = a.clone();
/// assert_eq!(a.next_u32(), b.next_u32());
/// assert!((0.0..1.0).contains(&a.f32()));
/// ```
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

/// The effective sampler seed of branch `index` of an `n>1` request
/// whose (explicit or fallback) seed is `seed`.
///
/// Branch 0 *is* the parent request — it keeps `seed` unchanged, so an
/// `n>1` request's first choice is byte-identical to the same request
/// with `n: 1`. Later branches mix the index through a splitmix-style
/// finalizer, giving each its own decorrelated stream while staying a
/// pure function of `(seed, index)` — which is what lets tests submit n
/// independent requests with `seed = branch_seed(s, i)` and demand byte
/// equality against the forked family.
///
/// ```
/// use webllm::sampler::branch_seed;
///
/// assert_eq!(branch_seed(42, 0), 42);
/// assert_ne!(branch_seed(42, 1), branch_seed(42, 2));
/// ```
pub fn branch_seed(seed: u64, index: usize) -> u64 {
    if index == 0 {
        return seed;
    }
    let mut x = seed ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl Pcg32 {
    /// Seed a generator (any seed is fine, including 0).
    pub fn new(seed: u64) -> Self {
        let mut rng = Self { state: 0, inc: (seed << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(0xDA3E39CB94B95BDB ^ seed);
        rng.next_u32();
        rng
    }

    /// Next 32 uniform bits (one PCG step).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(123);
        let mut b = Pcg32::new(123);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a: Vec<u32> = {
            let mut r = Pcg32::new(1);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Pcg32::new(2);
            (0..8).map(|_| r.next_u32()).collect()
        };
        assert_ne!(a, b);
    }

    #[test]
    fn f32_unit_interval_and_spread() {
        let mut r = Pcg32::new(7);
        let mut lo = false;
        let mut hi = false;
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            lo |= x < 0.1;
            hi |= x > 0.9;
        }
        assert!(lo && hi, "poor spread");
    }
}
