use super::*;
use crate::grammar::TokenBitmask;
use crate::testutil::prop::{PropRng, Runner};
use std::collections::HashMap;

fn logits(v: &[f32]) -> Vec<f32> {
    v.to_vec()
}

#[test]
fn greedy_picks_argmax() {
    let mut p = LogitsProcessor::new(SamplingParams::greedy(), 0);
    let mut l = logits(&[0.1, 2.0, -1.0, 1.9]);
    assert_eq!(p.sample(&mut l, None), 1);
}

#[test]
fn temperature_zero_is_deterministic_across_seeds() {
    for seed in 0..20 {
        let mut p = LogitsProcessor::new(SamplingParams::greedy(), seed);
        let mut l = logits(&[0.0, 0.5, 3.0, 0.1]);
        assert_eq!(p.sample(&mut l, None), 2);
    }
}

#[test]
fn seeded_sampling_reproducible() {
    let params = SamplingParams { seed: Some(42), ..Default::default() };
    let draw = |fallback| {
        let mut p = LogitsProcessor::new(params.clone(), fallback);
        let mut l = logits(&[1.0, 1.1, 0.9, 1.05]);
        p.sample(&mut l, None)
    };
    // explicit seed wins over fallback seed
    assert_eq!(draw(1), draw(999));
}

#[test]
fn top_k_restricts_support() {
    let params = SamplingParams { top_k: 2, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 7);
    for _ in 0..200 {
        let mut l = logits(&[5.0, 4.9, -10.0, -10.0]);
        let t = p.sample(&mut l, None);
        assert!(t == 0 || t == 1, "top_k=2 sampled {t}");
    }
}

#[test]
fn top_p_restricts_support() {
    // probs ~ [0.97, 0.01, 0.01, 0.01]; top_p=0.9 keeps only token 0.
    let params = SamplingParams { top_p: 0.9, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 11);
    for _ in 0..100 {
        let mut l = logits(&[6.0, 1.0, 1.0, 1.0]);
        assert_eq!(p.sample(&mut l, None), 0);
    }
}

#[test]
fn min_p_drops_tail() {
    let params = SamplingParams { min_p: 0.5, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 13);
    for _ in 0..100 {
        // p(0) >> others; min_p 0.5 bans everything below half of p_max.
        let mut l = logits(&[4.0, 2.0, 1.0, 0.0]);
        assert_eq!(p.sample(&mut l, None), 0);
    }
}

#[test]
fn grammar_mask_bans_tokens() {
    let mut p = LogitsProcessor::new(SamplingParams::default(), 3);
    let mask = vec![false, false, true, false];
    for _ in 0..50 {
        let mut l = logits(&[10.0, 9.0, -5.0, 8.0]);
        assert_eq!(p.sample(&mut l, Some(&mask)), 2);
    }
}

#[test]
fn fully_masked_falls_back_to_argmax() {
    let mut p = LogitsProcessor::new(SamplingParams::default(), 3);
    let mask = vec![false; 4];
    let mut l = logits(&[1.0, 3.0, 2.0, 0.0]);
    assert_eq!(p.sample(&mut l, Some(&mask)), 1);
}

#[test]
fn presence_penalty_discourages_repeats() {
    let params = SamplingParams {
        temperature: 0.0,
        presence_penalty: 2.0,
        ..Default::default()
    };
    let mut p = LogitsProcessor::new(params, 0);
    let mut l = logits(&[1.0, 0.5, 0.0]);
    assert_eq!(p.sample(&mut l, None), 0); // now observed
    let mut l = logits(&[1.0, 0.5, 0.0]);
    // 1.0 - 2.0 < 0.5 -> token 1 wins
    assert_eq!(p.sample(&mut l, None), 1);
}

#[test]
fn frequency_penalty_scales_with_count() {
    let params = SamplingParams {
        temperature: 0.0,
        frequency_penalty: 0.3,
        ..Default::default()
    };
    let mut p = LogitsProcessor::new(params, 0);
    p.observe(0);
    p.observe(0);
    p.observe(0); // count 3 -> -0.9
    let mut l = logits(&[1.0, 0.2]);
    assert_eq!(p.sample(&mut l, None), 1);
}

#[test]
fn repetition_penalty_divides_positive_multiplies_negative() {
    let params = SamplingParams { repetition_penalty: 2.0, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 0);
    p.observe(0);
    p.observe(1);
    let mut l = logits(&[4.0, -4.0, 0.0]);
    p.apply_penalties(&mut l);
    assert_eq!(l, vec![2.0, -8.0, 0.0]);
}

#[test]
fn logit_bias_applied() {
    let mut bias = HashMap::new();
    bias.insert(2u32, 100.0f32);
    let params = SamplingParams { temperature: 0.0, logit_bias: bias, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 0);
    let mut l = logits(&[5.0, 4.0, -50.0]);
    assert_eq!(p.sample(&mut l, None), 2);
}

#[test]
fn validation_catches_bad_ranges() {
    let ok = SamplingParams::default();
    assert!(ok.validate().is_ok());
    assert!(SamplingParams { temperature: 3.0, ..Default::default() }.validate().is_err());
    assert!(SamplingParams { top_p: 0.0, ..Default::default() }.validate().is_err());
    assert!(SamplingParams { presence_penalty: 5.0, ..Default::default() }.validate().is_err());
    assert!(SamplingParams { repetition_penalty: 0.0, ..Default::default() }.validate().is_err());
    let mut bias = HashMap::new();
    bias.insert(0u32, 500.0f32);
    assert!(SamplingParams { logit_bias: bias, ..Default::default() }.validate().is_err());
}

#[test]
fn prop_sampled_token_always_unmasked_and_in_range() {
    Runner::new("sampler_support", 300).run(|rng| {
        let n = 2 + rng.range(64);
        let mut l: Vec<f32> = (0..n).map(|_| (rng.f64() * 8.0 - 4.0) as f32).collect();
        let mask: Vec<bool> = (0..n).map(|_| rng.f64() < 0.7).collect();
        let any_allowed = mask.iter().any(|&b| b);
        let params = SamplingParams {
            temperature: [0.0, 0.5, 1.0, 1.5][rng.range(4)],
            top_p: [0.3, 0.9, 1.0][rng.range(3)],
            top_k: [0, 1, 4, 16][rng.range(4)],
            min_p: [0.0, 0.2][rng.range(2)],
            ..Default::default()
        };
        let mut p = LogitsProcessor::new(params, rng.u64());
        let t = p.sample(&mut l, Some(&mask)) as usize;
        if t >= n {
            return Err(format!("token {t} out of range {n}"));
        }
        if any_allowed && !mask[t] {
            return Err(format!("sampled masked token {t}"));
        }
        Ok(())
    });
}

#[test]
fn prop_temperature_sharpens_distribution() {
    // Low temperature must pick the argmax more often than high temperature.
    let count_argmax = |temp: f32| {
        let params = SamplingParams { temperature: temp, ..Default::default() };
        let mut hits = 0;
        for seed in 0..300u64 {
            let mut p = LogitsProcessor::new(params.clone(), seed);
            let mut l = logits(&[1.2, 1.0, 0.8, 0.6]);
            if p.sample(&mut l, None) == 0 {
                hits += 1;
            }
        }
        hits
    };
    assert!(count_argmax(0.2) > count_argmax(1.8));
}

#[test]
fn logprobs_report_sampled_token_and_top_k() {
    let params = SamplingParams {
        temperature: 0.0,
        logprobs: true,
        top_logprobs: 2,
        ..Default::default()
    };
    let mut p = LogitsProcessor::new(params, 0);
    let mut l = logits(&[2.0, 1.0, 0.0, -1.0]);
    let (token, lp) = p.sample_with_logprobs(&mut l, None);
    assert_eq!(token, 0);
    let lp = lp.unwrap();
    assert_eq!(lp.token, 0);
    // softmax over [2,1,0,-1]: p(0) ≈ 0.643 -> logprob ≈ -0.44
    assert!((lp.logprob - (-0.4402)).abs() < 1e-3, "{}", lp.logprob);
    assert_eq!(lp.top.len(), 2);
    assert_eq!(lp.top[0].0, 0);
    assert_eq!(lp.top[1].0, 1);
    assert!(lp.top[0].1 > lp.top[1].1);
}

#[test]
fn logprobs_disabled_returns_none() {
    let mut p = LogitsProcessor::new(SamplingParams::greedy(), 0);
    let mut l = logits(&[1.0, 0.0]);
    let (_, lp) = p.sample_with_logprobs(&mut l, None);
    assert!(lp.is_none());
}

#[test]
fn logprobs_respect_mask() {
    let params = SamplingParams {
        temperature: 0.0,
        logprobs: true,
        top_logprobs: 4,
        ..Default::default()
    };
    let mut p = LogitsProcessor::new(params, 0);
    let mask = vec![false, true, true, false];
    let mut l = logits(&[9.0, 1.0, 0.5, 8.0]);
    let (token, lp) = p.sample_with_logprobs(&mut l, Some(&mask));
    assert_eq!(token, 1);
    let lp = lp.unwrap();
    // masked tokens can't appear among the top alternatives
    assert!(lp.top.iter().all(|&(t, _)| t == 1 || t == 2), "{:?}", lp.top);
    // distribution renormalized over the unmasked support
    let total: f32 = lp.top.iter().map(|&(_, l)| l.exp()).sum();
    assert!((total - 1.0).abs() < 1e-3, "{total}");
}

// -- fused-pipeline equivalence ----------------------------------------------
//
// The fused hot path (bitmask candidate collection + partial selection +
// lazy descending walk) must be token-for-token identical to a naive
// full-sort implementation of the same spec (logits.rs module docs), and
// the packed-mask path must be identical to the legacy `&[bool]` path.

/// Naive full-sort reference of the sampling spec. Deliberately simple:
/// every ordering step is a full `sort_unstable_by` under the same total
/// order the fused path uses.
fn reference_sample(
    logits: &[f32],
    mask: Option<&[bool]>,
    extra: &[u32],
    params: &SamplingParams,
    rng: &mut Pcg32,
) -> u32 {
    fn cmp_desc(a: &(u32, f32), b: &(u32, f32)) -> std::cmp::Ordering {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.0.cmp(&b.0))
    }
    fn argmax(logits: &[f32]) -> u32 {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            if l > best_v {
                best_v = l;
                best = i;
            }
        }
        best as u32
    }

    let greedy = params.temperature == 0.0;
    let inv_t = if greedy { 1.0 } else { 1.0 / params.temperature };
    let mut cands: Vec<(u32, f32)> = Vec::new();
    for (i, &l) in logits.iter().enumerate() {
        let ok = match mask {
            Some(m) => m[i] || extra.contains(&(i as u32)),
            None => true,
        };
        // mirror the fused spec: candidacy tests the *scaled* value
        let s = l * inv_t;
        if ok && s.is_finite() {
            cands.push((i as u32, s));
        }
    }
    if cands.is_empty() {
        return argmax(logits);
    }
    if greedy {
        let mut best = cands[0];
        for &c in &cands[1..] {
            if c.1 > best.1 {
                best = c;
            }
        }
        return best.0;
    }
    let max_l = cands.iter().fold(f32::NEG_INFINITY, |a, &(_, l)| a.max(l));
    for c in &mut cands {
        c.1 = (c.1 - max_l).exp();
    }
    if params.top_k > 0 && params.top_k < cands.len() {
        cands.sort_unstable_by(cmp_desc);
        cands.truncate(params.top_k);
    }
    if params.min_p > 0.0 {
        cands.retain(|&(_, e)| e >= params.min_p);
    }
    let total: f32 = cands.iter().map(|&(_, e)| e).sum();
    let mut kept_total = total;
    if params.top_p < 1.0 {
        cands.sort_unstable_by(cmp_desc);
        let target = params.top_p * total;
        let mut cum = 0.0f32;
        let mut kept = cands.len();
        for (i, &(_, e)) in cands.iter().enumerate() {
            cum += e;
            if cum >= target {
                kept = i + 1;
                kept_total = cum;
                break;
            }
        }
        cands.truncate(kept);
    }
    cands.sort_unstable_by(cmp_desc);
    let r = rng.f32();
    let target = r * kept_total;
    let mut cum = 0.0f32;
    for &(t, e) in &cands {
        cum += e;
        if target < cum {
            return t;
        }
    }
    cands.last().unwrap().0
}

/// Draw one random sampling configuration (shared by the equivalence props).
fn arb_params(rng: &mut PropRng) -> SamplingParams {
    SamplingParams {
        temperature: [0.0, 0.3, 0.8, 1.0, 1.7][rng.range(5)],
        top_p: [0.2, 0.5, 0.9, 0.97, 1.0][rng.range(5)],
        top_k: [0, 1, 2, 8, 40, 1000][rng.range(6)],
        min_p: [0.0, 0.05, 0.3][rng.range(3)],
        ..Default::default()
    }
}

fn arb_logits(rng: &mut PropRng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.f64() * 16.0 - 8.0) as f32).collect()
}

#[test]
fn prop_fused_matches_full_sort_reference() {
    Runner::new("fused_vs_reference", 400).run(|rng| {
        let n = 2 + rng.range(500);
        let logits = arb_logits(rng, n);
        let params = arb_params(rng);
        let seed = rng.u64();

        // Optional mask of random density, optional extra allowances.
        let with_mask = rng.range(4) != 0;
        let density = [0.02, 0.2, 0.7][rng.range(3)];
        let bools: Option<Vec<bool>> =
            with_mask.then(|| (0..n).map(|_| rng.f64() < density).collect());
        let extra: Vec<u32> = if with_mask && rng.bool() {
            (0..1 + rng.range(2)).map(|_| rng.range(n) as u32).collect()
        } else {
            Vec::new()
        };

        // Fused path, with some tokens pre-observed so penalties are live.
        let observed: Vec<u32> = (0..rng.range(8)).map(|_| rng.range(n) as u32).collect();
        let mut params_pen = params.clone();
        params_pen.repetition_penalty = [1.0, 1.3][rng.range(2)];
        params_pen.presence_penalty = [0.0, 0.5][rng.range(2)];
        params_pen.seed = Some(seed);
        let mut p = LogitsProcessor::new(params_pen.clone(), 0);
        for &t in &observed {
            p.observe(t);
        }
        let mask = bools.as_deref().map(TokenBitmask::from_bools);
        let mut row = logits.clone();
        let got = p.sample_masked(&mut row, mask.as_ref(), &extra);

        // Reference: identical penalty application (same code, same
        // floats), then the naive full-sort pipeline with a twin RNG.
        let mut ref_row = logits.clone();
        let mut pen = LogitsProcessor::new(params_pen.clone(), 0);
        for &t in &observed {
            pen.observe(t);
        }
        pen.apply_penalties(&mut ref_row);
        let mut twin_rng = Pcg32::new(seed);
        let want =
            reference_sample(&ref_row, bools.as_deref(), &extra, &params_pen, &mut twin_rng);

        if got != want {
            return Err(format!(
                "fused {got} != reference {want} (n={n}, params={params_pen:?}, \
                 mask={}, extra={extra:?})",
                bools.is_some()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_bitmask_path_matches_bool_path() {
    Runner::new("bitmask_vs_bools", 300).run(|rng| {
        let n = 2 + rng.range(300);
        let logits = arb_logits(rng, n);
        let mut params = arb_params(rng);
        params.seed = Some(rng.u64());
        let bools: Vec<bool> = (0..n).map(|_| rng.f64() < 0.5).collect();

        let mut pa = LogitsProcessor::new(params.clone(), 0);
        let mut row_a = logits.clone();
        let a = pa.sample(&mut row_a, Some(&bools));

        let mut pb = LogitsProcessor::new(params.clone(), 0);
        let mask = TokenBitmask::from_bools(&bools);
        let mut row_b = logits.clone();
        let b = pb.sample_masked(&mut row_b, Some(&mask), &[]);

        if a != b {
            return Err(format!("bool path {a} != bitmask path {b} (n={n}, params={params:?})"));
        }
        Ok(())
    });
}

#[test]
fn prop_fused_sampled_token_respects_bitmask() {
    Runner::new("fused_support", 300).run(|rng| {
        let n = 2 + rng.range(128);
        let mut logits = arb_logits(rng, n);
        let bools: Vec<bool> = (0..n).map(|_| rng.f64() < 0.6).collect();
        let any = bools.iter().any(|&b| b);
        let mask = TokenBitmask::from_bools(&bools);
        let mut params = arb_params(rng);
        params.seed = Some(rng.u64());
        let mut p = LogitsProcessor::new(params, 0);
        let t = p.sample_masked(&mut logits, Some(&mask), &[]) as usize;
        if t >= n {
            return Err(format!("token {t} out of range {n}"));
        }
        if any && !bools[t] {
            return Err(format!("sampled banned token {t}"));
        }
        Ok(())
    });
}

#[test]
fn extra_allowance_unbans_eos() {
    // A mask that bans everything except token 1, with token 3 (the "EOS")
    // granted via allow_extra: both must be samplable, nothing else.
    let bools = vec![false, true, false, false, false];
    let mask = TokenBitmask::from_bools(&bools);
    let mut seen = [false; 5];
    for seed in 0..200u64 {
        let mut p = LogitsProcessor::new(
            SamplingParams { seed: Some(seed), ..Default::default() },
            0,
        );
        let mut l = vec![1.0f32, 1.0, 1.0, 1.0, 1.0];
        let t = p.sample_masked(&mut l, Some(&mask), &[3]) as usize;
        assert!(t == 1 || t == 3, "sampled {t}");
        seen[t] = true;
    }
    assert!(seen[1] && seen[3], "both allowed tokens should appear: {seen:?}");
}

#[test]
fn fully_banned_bitmask_falls_back_to_argmax() {
    let mask = TokenBitmask::new(4);
    let mut p = LogitsProcessor::new(SamplingParams::default(), 3);
    let mut l = vec![1.0f32, 3.0, 2.0, 0.0];
    assert_eq!(p.sample_masked(&mut l, Some(&mask), &[]), 1);
}

#[test]
fn masked_greedy_picks_best_allowed() {
    let mask = TokenBitmask::from_bools(&[false, false, true, true, false]);
    let mut p = LogitsProcessor::new(SamplingParams::greedy(), 0);
    let mut l = vec![9.0f32, 8.0, 1.0, 2.0, 7.0];
    assert_eq!(p.sample_masked(&mut l, Some(&mask), &[]), 3);
    // extra allowance can win if it has the best logit
    let mut p = LogitsProcessor::new(SamplingParams::greedy(), 0);
    let mut l = vec![9.0f32, 8.0, 1.0, 2.0, 7.0];
    assert_eq!(p.sample_masked(&mut l, Some(&mask), &[0]), 0);
}

#[test]
fn logprobs_masked_reports_only_allowed_tokens() {
    let params = SamplingParams {
        temperature: 0.0,
        logprobs: true,
        top_logprobs: 4,
        ..Default::default()
    };
    let mut p = LogitsProcessor::new(params, 0);
    let mask = TokenBitmask::from_bools(&[false, true, true, false]);
    let mut l = vec![9.0f32, 1.0, 0.5, 8.0];
    let (token, lp) = p.sample_with_logprobs_masked(&mut l, Some(&mask), &[3]);
    // token 3 is granted via the EOS allowance and has the best logit
    assert_eq!(token, 3);
    let lp = lp.unwrap();
    assert!(
        lp.top.iter().all(|&(t, _)| t == 1 || t == 2 || t == 3),
        "{:?}",
        lp.top
    );
}

// -- drift sentinel vs the pre-refactor (seed) sampler ------------------------
//
// The fused pipeline re-specified the arithmetic (total order with
// token-id tie-break, unnormalized-mass comparisons) rather than
// replicating the seed's repeated renormalization bit-for-bit. For the
// engine-visible contract that matters two ways: greedy must be exactly
// unchanged, and stochastic draws may differ from the seed only when a
// truncation cut or the inverse-CDF draw lands within float-epsilon of a
// boundary (or on an exact logit tie, where the seed's sort order was
// itself unspecified). These tests pin both.

/// The seed's sampler, verbatim: `-inf` mask materialization, full
/// descending sort with no tie-breaker, softmax + renormalization after
/// each truncation, `r < cum` draw over normalized probs.
fn seed_sample(
    logits: &mut [f32],
    mask: Option<&[bool]>,
    p: &SamplingParams,
    rng: &mut Pcg32,
) -> u32 {
    fn argmax(logits: &[f32]) -> u32 {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            if l > best_v {
                best_v = l;
                best = i;
            }
        }
        best as u32
    }
    let mut fallback = None;
    if let Some(mask) = mask {
        if !mask.iter().any(|&ok| ok) {
            fallback = Some(argmax(logits));
        }
        for (l, &ok) in logits.iter_mut().zip(mask) {
            if !ok {
                *l = f32::NEG_INFINITY;
            }
        }
    }
    if let Some(t) = fallback {
        return t;
    }
    if p.temperature == 0.0 {
        return argmax(logits);
    }
    let inv_t = 1.0 / p.temperature;
    let mut scratch: Vec<(u32, f32)> = Vec::new();
    for (i, &l) in logits.iter().enumerate() {
        if l.is_finite() {
            scratch.push((i as u32, l * inv_t));
        }
    }
    if scratch.is_empty() {
        return argmax(logits);
    }
    scratch.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
    let mut n = scratch.len();
    if p.top_k > 0 {
        n = n.min(p.top_k);
    }
    let m = scratch[0].1;
    let mut total = 0.0f32;
    let mut probs: Vec<f32> = Vec::with_capacity(n);
    for &(_, l) in &scratch[..n] {
        let e = (l - m).exp();
        probs.push(e);
        total += e;
    }
    for q in &mut probs {
        *q /= total;
    }
    if p.min_p > 0.0 {
        let floor = p.min_p * probs[0];
        let keep = probs.iter().take_while(|&&q| q >= floor).count().max(1);
        if keep < n {
            n = keep;
            let t: f32 = probs[..n].iter().sum();
            probs.truncate(n);
            for q in &mut probs {
                *q /= t;
            }
        }
    }
    if p.top_p < 1.0 {
        let mut cum = 0.0f32;
        let mut keep = n;
        for (i, &q) in probs.iter().enumerate() {
            cum += q;
            if cum >= p.top_p {
                keep = i + 1;
                break;
            }
        }
        if keep < n {
            n = keep;
            let t: f32 = probs[..n].iter().sum();
            probs.truncate(n);
            for q in &mut probs {
                *q /= t;
            }
        }
    }
    let r = rng.f32();
    let mut cum = 0.0f32;
    for (i, &q) in probs[..n].iter().enumerate() {
        cum += q;
        if r < cum {
            return scratch[i].0;
        }
    }
    scratch[n - 1].0
}

#[test]
fn prop_greedy_has_zero_drift_vs_seed_sampler() {
    Runner::new("seed_drift_greedy", 200).run(|rng| {
        let n = 2 + rng.range(300);
        let logits = arb_logits(rng, n);
        let with_mask = rng.range(4) != 0;
        let bools: Option<Vec<bool>> =
            with_mask.then(|| (0..n).map(|_| rng.f64() < 0.4).collect());

        let mut row_a = logits.clone();
        let mut seed_rng = Pcg32::new(1);
        let a = seed_sample(&mut row_a, bools.as_deref(), &SamplingParams::greedy(), &mut seed_rng);

        let mut p = LogitsProcessor::new(
            SamplingParams { temperature: 0.0, seed: Some(1), ..Default::default() },
            0,
        );
        let mask = bools.as_deref().map(TokenBitmask::from_bools);
        let mut row_b = logits.clone();
        let b = p.sample_masked(&mut row_b, mask.as_ref(), &[]);
        if a != b {
            return Err(format!("greedy drift: seed {a} vs fused {b} (n={n})"));
        }
        Ok(())
    });
}

#[test]
fn stochastic_drift_vs_seed_sampler_is_boundary_only() {
    // Deterministic corpus (fixed generator seed). Expected mismatches: 0;
    // the <=1% allowance exists only for the float-epsilon boundary cases
    // described above, so a real behavioral regression (wrong kept set,
    // wrong walk order, wrong RNG usage) fails loudly.
    let cases = 300usize;
    let mut gen = PropRng::new(0xD31F7);
    let mut mismatches = Vec::new();
    for case in 0..cases {
        let n = 2 + gen.range(200);
        let logits = arb_logits(&mut gen, n);
        let params = SamplingParams {
            temperature: [0.5, 0.8, 1.0, 1.3][gen.range(4)],
            top_p: [0.3, 0.9, 1.0][gen.range(3)],
            top_k: [0, 5, 40][gen.range(3)],
            min_p: [0.0, 0.1][gen.range(2)],
            seed: Some(gen.u64()),
            ..Default::default()
        };
        let with_mask = gen.range(2) == 0;
        let bools: Option<Vec<bool>> =
            with_mask.then(|| (0..n).map(|_| gen.f64() < 0.5).collect());

        let mut row_a = logits.clone();
        let mut seed_rng = Pcg32::new(params.seed.unwrap());
        let a = seed_sample(&mut row_a, bools.as_deref(), &params, &mut seed_rng);

        let mut p = LogitsProcessor::new(params.clone(), 0);
        let mask = bools.as_deref().map(TokenBitmask::from_bools);
        let mut row_b = logits.clone();
        let b = p.sample_masked(&mut row_b, mask.as_ref(), &[]);
        if a != b {
            mismatches.push((case, a, b));
        }
    }
    assert!(
        mismatches.len() <= cases / 100,
        "stochastic drift vs seed sampler beyond boundary tolerance: {mismatches:?}"
    );
}
