use super::*;
use crate::testutil::prop::Runner;
use std::collections::HashMap;

fn logits(v: &[f32]) -> Vec<f32> {
    v.to_vec()
}

#[test]
fn greedy_picks_argmax() {
    let mut p = LogitsProcessor::new(SamplingParams::greedy(), 0);
    let mut l = logits(&[0.1, 2.0, -1.0, 1.9]);
    assert_eq!(p.sample(&mut l, None), 1);
}

#[test]
fn temperature_zero_is_deterministic_across_seeds() {
    for seed in 0..20 {
        let mut p = LogitsProcessor::new(SamplingParams::greedy(), seed);
        let mut l = logits(&[0.0, 0.5, 3.0, 0.1]);
        assert_eq!(p.sample(&mut l, None), 2);
    }
}

#[test]
fn seeded_sampling_reproducible() {
    let params = SamplingParams { seed: Some(42), ..Default::default() };
    let draw = |fallback| {
        let mut p = LogitsProcessor::new(params.clone(), fallback);
        let mut l = logits(&[1.0, 1.1, 0.9, 1.05]);
        p.sample(&mut l, None)
    };
    // explicit seed wins over fallback seed
    assert_eq!(draw(1), draw(999));
}

#[test]
fn top_k_restricts_support() {
    let params = SamplingParams { top_k: 2, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 7);
    for _ in 0..200 {
        let mut l = logits(&[5.0, 4.9, -10.0, -10.0]);
        let t = p.sample(&mut l, None);
        assert!(t == 0 || t == 1, "top_k=2 sampled {t}");
    }
}

#[test]
fn top_p_restricts_support() {
    // probs ~ [0.97, 0.01, 0.01, 0.01]; top_p=0.9 keeps only token 0.
    let params = SamplingParams { top_p: 0.9, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 11);
    for _ in 0..100 {
        let mut l = logits(&[6.0, 1.0, 1.0, 1.0]);
        assert_eq!(p.sample(&mut l, None), 0);
    }
}

#[test]
fn min_p_drops_tail() {
    let params = SamplingParams { min_p: 0.5, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 13);
    for _ in 0..100 {
        // p(0) >> others; min_p 0.5 bans everything below half of p_max.
        let mut l = logits(&[4.0, 2.0, 1.0, 0.0]);
        assert_eq!(p.sample(&mut l, None), 0);
    }
}

#[test]
fn grammar_mask_bans_tokens() {
    let mut p = LogitsProcessor::new(SamplingParams::default(), 3);
    let mask = vec![false, false, true, false];
    for _ in 0..50 {
        let mut l = logits(&[10.0, 9.0, -5.0, 8.0]);
        assert_eq!(p.sample(&mut l, Some(&mask)), 2);
    }
}

#[test]
fn fully_masked_falls_back_to_argmax() {
    let mut p = LogitsProcessor::new(SamplingParams::default(), 3);
    let mask = vec![false; 4];
    let mut l = logits(&[1.0, 3.0, 2.0, 0.0]);
    assert_eq!(p.sample(&mut l, Some(&mask)), 1);
}

#[test]
fn presence_penalty_discourages_repeats() {
    let params = SamplingParams {
        temperature: 0.0,
        presence_penalty: 2.0,
        ..Default::default()
    };
    let mut p = LogitsProcessor::new(params, 0);
    let mut l = logits(&[1.0, 0.5, 0.0]);
    assert_eq!(p.sample(&mut l, None), 0); // now observed
    let mut l = logits(&[1.0, 0.5, 0.0]);
    // 1.0 - 2.0 < 0.5 -> token 1 wins
    assert_eq!(p.sample(&mut l, None), 1);
}

#[test]
fn frequency_penalty_scales_with_count() {
    let params = SamplingParams {
        temperature: 0.0,
        frequency_penalty: 0.3,
        ..Default::default()
    };
    let mut p = LogitsProcessor::new(params, 0);
    p.observe(0);
    p.observe(0);
    p.observe(0); // count 3 -> -0.9
    let mut l = logits(&[1.0, 0.2]);
    assert_eq!(p.sample(&mut l, None), 1);
}

#[test]
fn repetition_penalty_divides_positive_multiplies_negative() {
    let params = SamplingParams { repetition_penalty: 2.0, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 0);
    p.observe(0);
    p.observe(1);
    let mut l = logits(&[4.0, -4.0, 0.0]);
    p.apply_penalties(&mut l);
    assert_eq!(l, vec![2.0, -8.0, 0.0]);
}

#[test]
fn logit_bias_applied() {
    let mut bias = HashMap::new();
    bias.insert(2u32, 100.0f32);
    let params = SamplingParams { temperature: 0.0, logit_bias: bias, ..Default::default() };
    let mut p = LogitsProcessor::new(params, 0);
    let mut l = logits(&[5.0, 4.0, -50.0]);
    assert_eq!(p.sample(&mut l, None), 2);
}

#[test]
fn validation_catches_bad_ranges() {
    let ok = SamplingParams::default();
    assert!(ok.validate().is_ok());
    assert!(SamplingParams { temperature: 3.0, ..Default::default() }.validate().is_err());
    assert!(SamplingParams { top_p: 0.0, ..Default::default() }.validate().is_err());
    assert!(SamplingParams { presence_penalty: 5.0, ..Default::default() }.validate().is_err());
    assert!(SamplingParams { repetition_penalty: 0.0, ..Default::default() }.validate().is_err());
    let mut bias = HashMap::new();
    bias.insert(0u32, 500.0f32);
    assert!(SamplingParams { logit_bias: bias, ..Default::default() }.validate().is_err());
}

#[test]
fn prop_sampled_token_always_unmasked_and_in_range() {
    Runner::new("sampler_support", 300).run(|rng| {
        let n = 2 + rng.range(64);
        let mut l: Vec<f32> = (0..n).map(|_| (rng.f64() * 8.0 - 4.0) as f32).collect();
        let mask: Vec<bool> = (0..n).map(|_| rng.f64() < 0.7).collect();
        let any_allowed = mask.iter().any(|&b| b);
        let params = SamplingParams {
            temperature: [0.0, 0.5, 1.0, 1.5][rng.range(4)],
            top_p: [0.3, 0.9, 1.0][rng.range(3)],
            top_k: [0, 1, 4, 16][rng.range(4)],
            min_p: [0.0, 0.2][rng.range(2)],
            ..Default::default()
        };
        let mut p = LogitsProcessor::new(params, rng.u64());
        let t = p.sample(&mut l, Some(&mask)) as usize;
        if t >= n {
            return Err(format!("token {t} out of range {n}"));
        }
        if any_allowed && !mask[t] {
            return Err(format!("sampled masked token {t}"));
        }
        Ok(())
    });
}

#[test]
fn prop_temperature_sharpens_distribution() {
    // Low temperature must pick the argmax more often than high temperature.
    let count_argmax = |temp: f32| {
        let params = SamplingParams { temperature: temp, ..Default::default() };
        let mut hits = 0;
        for seed in 0..300u64 {
            let mut p = LogitsProcessor::new(params.clone(), seed);
            let mut l = logits(&[1.2, 1.0, 0.8, 0.6]);
            if p.sample(&mut l, None) == 0 {
                hits += 1;
            }
        }
        hits
    };
    assert!(count_argmax(0.2) > count_argmax(1.8));
}

#[test]
fn logprobs_report_sampled_token_and_top_k() {
    let params = SamplingParams {
        temperature: 0.0,
        logprobs: true,
        top_logprobs: 2,
        ..Default::default()
    };
    let mut p = LogitsProcessor::new(params, 0);
    let mut l = logits(&[2.0, 1.0, 0.0, -1.0]);
    let (token, lp) = p.sample_with_logprobs(&mut l, None);
    assert_eq!(token, 0);
    let lp = lp.unwrap();
    assert_eq!(lp.token, 0);
    // softmax over [2,1,0,-1]: p(0) ≈ 0.643 -> logprob ≈ -0.44
    assert!((lp.logprob - (-0.4402)).abs() < 1e-3, "{}", lp.logprob);
    assert_eq!(lp.top.len(), 2);
    assert_eq!(lp.top[0].0, 0);
    assert_eq!(lp.top[1].0, 1);
    assert!(lp.top[0].1 > lp.top[1].1);
}

#[test]
fn logprobs_disabled_returns_none() {
    let mut p = LogitsProcessor::new(SamplingParams::greedy(), 0);
    let mut l = logits(&[1.0, 0.0]);
    let (_, lp) = p.sample_with_logprobs(&mut l, None);
    assert!(lp.is_none());
}

#[test]
fn logprobs_respect_mask() {
    let params = SamplingParams {
        temperature: 0.0,
        logprobs: true,
        top_logprobs: 4,
        ..Default::default()
    };
    let mut p = LogitsProcessor::new(params, 0);
    let mask = vec![false, true, true, false];
    let mut l = logits(&[9.0, 1.0, 0.5, 8.0]);
    let (token, lp) = p.sample_with_logprobs(&mut l, Some(&mask));
    assert_eq!(token, 1);
    let lp = lp.unwrap();
    // masked tokens can't appear among the top alternatives
    assert!(lp.top.iter().all(|&(t, _)| t == 1 || t == 2), "{:?}", lp.top);
    // distribution renormalized over the unmasked support
    let total: f32 = lp.top.iter().map(|&(_, l)| l.exp()).sum();
    assert!((total - 1.0).abs() < 1e-3, "{total}");
}
