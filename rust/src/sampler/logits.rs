//! Logits processing pipeline.

use super::Pcg32;
use std::collections::HashMap;

/// Log-probability record for one sampled token (OpenAI `logprobs`).
#[derive(Clone, Debug, PartialEq)]
pub struct TokenLogprob {
    pub token: u32,
    pub logprob: f32,
    /// The `top_logprobs` most likely alternatives at this position.
    pub top: Vec<(u32, f32)>,
}

/// Per-request sampling controls (OpenAI-style names and semantics).
#[derive(Clone, Debug)]
pub struct SamplingParams {
    /// 0.0 => greedy argmax.
    pub temperature: f32,
    /// Nucleus sampling threshold in (0, 1]; 1.0 disables.
    pub top_p: f32,
    /// Keep only the k most likely tokens; 0 disables.
    pub top_k: usize,
    /// Drop tokens below min_p * max_prob; 0.0 disables.
    pub min_p: f32,
    /// > 1.0 penalizes tokens already generated (multiplicative, CTRL-style).
    pub repetition_penalty: f32,
    /// Additive penalty on any token that has appeared (OpenAI presence).
    pub presence_penalty: f32,
    /// Additive penalty scaled by occurrence count (OpenAI frequency).
    pub frequency_penalty: f32,
    /// token id -> additive bias in [-100, 100].
    pub logit_bias: HashMap<u32, f32>,
    /// RNG seed (None => engine picks one per request).
    pub seed: Option<u64>,
    /// Return per-token log-probabilities (OpenAI `logprobs`).
    pub logprobs: bool,
    /// Number of top alternatives per position (OpenAI `top_logprobs`).
    pub top_logprobs: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self {
            temperature: 1.0,
            top_p: 1.0,
            top_k: 0,
            min_p: 0.0,
            repetition_penalty: 1.0,
            presence_penalty: 0.0,
            frequency_penalty: 0.0,
            logit_bias: HashMap::new(),
            seed: None,
            logprobs: false,
            top_logprobs: 0,
        }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self { temperature: 0.0, ..Self::default() }
    }

    /// Validate ranges (the API layer surfaces these as 400s).
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=2.0).contains(&self.temperature) {
            return Err(format!("temperature {} not in [0, 2]", self.temperature));
        }
        if !(0.0..=1.0).contains(&self.top_p) || self.top_p == 0.0 {
            return Err(format!("top_p {} not in (0, 1]", self.top_p));
        }
        if !(0.0..=1.0).contains(&self.min_p) {
            return Err(format!("min_p {} not in [0, 1]", self.min_p));
        }
        if !(-2.0..=2.0).contains(&self.presence_penalty) {
            return Err(format!("presence_penalty {} not in [-2, 2]", self.presence_penalty));
        }
        if !(-2.0..=2.0).contains(&self.frequency_penalty) {
            return Err(format!("frequency_penalty {} not in [-2, 2]", self.frequency_penalty));
        }
        if self.repetition_penalty <= 0.0 {
            return Err("repetition_penalty must be > 0".into());
        }
        for (&t, &b) in &self.logit_bias {
            if !(-100.0..=100.0).contains(&b) {
                return Err(format!("logit_bias[{t}] = {b} not in [-100, 100]"));
            }
        }
        if self.top_logprobs > 20 {
            return Err(format!("top_logprobs {} > 20", self.top_logprobs));
        }
        Ok(())
    }
}

/// Stateful per-sequence processor: tracks occurrence counts for the
/// penalty terms and owns the request RNG.
pub struct LogitsProcessor {
    params: SamplingParams,
    rng: Pcg32,
    counts: HashMap<u32, u32>,
    /// Scratch reused across steps to keep the decode hot path allocation-free.
    scratch: Vec<(u32, f32)>,
}

impl LogitsProcessor {
    pub fn new(params: SamplingParams, fallback_seed: u64) -> Self {
        let seed = params.seed.unwrap_or(fallback_seed);
        Self { params, rng: Pcg32::new(seed), counts: HashMap::new(), scratch: Vec::new() }
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Record a token that entered the context (prompt or generated) so
    /// penalties see it.
    pub fn observe(&mut self, token: u32) {
        *self.counts.entry(token).or_insert(0) += 1;
    }

    /// Apply penalties + bias in place (steps 1-2 of the pipeline).
    pub fn apply_penalties(&self, logits: &mut [f32]) {
        let p = &self.params;
        if p.repetition_penalty != 1.0 || p.presence_penalty != 0.0 || p.frequency_penalty != 0.0
        {
            for (&tok, &count) in &self.counts {
                let Some(l) = logits.get_mut(tok as usize) else { continue };
                if p.repetition_penalty != 1.0 {
                    *l = if *l > 0.0 { *l / p.repetition_penalty } else { *l * p.repetition_penalty };
                }
                *l -= p.presence_penalty;
                *l -= p.frequency_penalty * count as f32;
            }
        }
        for (&tok, &bias) in &p.logit_bias {
            if let Some(l) = logits.get_mut(tok as usize) {
                *l += bias;
            }
        }
    }

    /// Full pipeline on a raw logits row; `mask` (from the grammar engine)
    /// bans token i when `mask[i]` is false. Returns the sampled token.
    pub fn sample(&mut self, logits: &mut [f32], mask: Option<&[bool]>) -> u32 {
        self.apply_penalties(logits);
        // Fallback for a degenerate (fully-masking) grammar state: the
        // pre-mask argmax, so generation still makes progress.
        let mut fallback = None;
        if let Some(mask) = mask {
            debug_assert_eq!(mask.len(), logits.len());
            if !mask.iter().any(|&ok| ok) {
                fallback = Some(argmax(logits));
            }
            for (l, &ok) in logits.iter_mut().zip(mask) {
                if !ok {
                    *l = f32::NEG_INFINITY;
                }
            }
        }

        let token = match fallback {
            Some(t) => t,
            None if self.params.temperature == 0.0 => argmax(logits),
            None => self.sample_stochastic(logits),
        };
        self.observe(token);
        token
    }

    /// Like `sample`, additionally returning the sampled token's logprob
    /// and the top-`top_logprobs` alternatives, computed over the final
    /// (post-penalty, post-mask, temperature-scaled) distribution —
    /// OpenAI semantics.
    pub fn sample_with_logprobs(
        &mut self,
        logits: &mut [f32],
        mask: Option<&[bool]>,
    ) -> (u32, Option<TokenLogprob>) {
        let token = self.sample(logits, mask);
        if !self.params.logprobs {
            return (token, None);
        }
        // `logits` now holds the post-penalty/mask values (sample mutates
        // in place). Log-softmax at the effective temperature.
        let inv_t = if self.params.temperature > 0.0 { 1.0 / self.params.temperature } else { 1.0 };
        let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut log_z = 0.0f32;
        for &l in logits.iter() {
            if l.is_finite() {
                log_z += ((l - m) * inv_t).exp();
            }
        }
        let log_z = log_z.ln();
        let lp = |i: u32| -> f32 {
            let l = logits[i as usize];
            if l.is_finite() { (l - m) * inv_t - log_z } else { f32::NEG_INFINITY }
        };
        let mut top: Vec<(u32, f32)> = Vec::new();
        if self.params.top_logprobs > 0 {
            let mut idx: Vec<u32> = (0..logits.len() as u32)
                .filter(|&i| logits[i as usize].is_finite())
                .collect();
            let k = self.params.top_logprobs.min(idx.len());
            idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
                logits[b as usize]
                    .partial_cmp(&logits[a as usize])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            idx.truncate(k);
            idx.sort_unstable_by(|&a, &b| {
                logits[b as usize]
                    .partial_cmp(&logits[a as usize])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            top = idx.into_iter().map(|i| (i, lp(i))).collect();
        }
        (token, Some(TokenLogprob { token, logprob: lp(token), top }))
    }

    fn sample_stochastic(&mut self, logits: &[f32]) -> u32 {
        let p = &self.params;
        let inv_t = 1.0 / p.temperature;

        // Collect finite candidates (scratch reuse).
        self.scratch.clear();
        for (i, &l) in logits.iter().enumerate() {
            if l.is_finite() {
                self.scratch.push((i as u32, l * inv_t));
            }
        }
        if self.scratch.is_empty() {
            // Everything masked: fall back to argmax over raw logits.
            return argmax(logits);
        }

        // Sort descending by logit; truncation filters operate on prefixes.
        self.scratch
            .sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));

        let mut n = self.scratch.len();
        if p.top_k > 0 {
            n = n.min(p.top_k);
        }

        // Softmax over the kept prefix (max-subtracted).
        let m = self.scratch[0].1;
        let mut total = 0.0f32;
        let mut probs: Vec<f32> = Vec::with_capacity(n);
        for &(_, l) in &self.scratch[..n] {
            let e = (l - m).exp();
            probs.push(e);
            total += e;
        }
        for q in &mut probs {
            *q /= total;
        }

        // min-p: drop tokens below min_p * p_max.
        if p.min_p > 0.0 {
            let floor = p.min_p * probs[0];
            let keep = probs.iter().take_while(|&&q| q >= floor).count().max(1);
            if keep < n {
                n = keep;
                let t: f32 = probs[..n].iter().sum();
                probs.truncate(n);
                for q in &mut probs {
                    *q /= t;
                }
            }
        }

        // top-p nucleus: smallest prefix with cumulative mass >= top_p.
        if p.top_p < 1.0 {
            let mut cum = 0.0f32;
            let mut keep = n;
            for (i, &q) in probs.iter().enumerate() {
                cum += q;
                if cum >= p.top_p {
                    keep = i + 1;
                    break;
                }
            }
            if keep < n {
                n = keep;
                let t: f32 = probs[..n].iter().sum();
                probs.truncate(n);
                for q in &mut probs {
                    *q /= t;
                }
            }
        }

        // Inverse-CDF draw.
        let r = self.rng.f32();
        let mut cum = 0.0f32;
        for (i, &q) in probs[..n].iter().enumerate() {
            cum += q;
            if r < cum {
                return self.scratch[i].0;
            }
        }
        self.scratch[n - 1].0
    }
}

fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        if l > best_v {
            best_v = l;
            best = i;
        }
    }
    best as u32
}
