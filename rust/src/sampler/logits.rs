//! Logits processing pipeline.
//!
//! Two entry paths share one fused core:
//!
//! * [`LogitsProcessor::sample_masked`] — the engine's decode hot path.
//!   Takes the grammar mask as a packed [`TokenBitmask`] and performs
//!   candidate collection, top-k/top-p/min-p truncation, and the final
//!   draw without allocating and without a full sort: banned tokens are
//!   skipped 64-at-a-time on zero mask words, top-k uses
//!   `select_nth_unstable`, and top-p / the inverse-CDF draw walk a
//!   lazily-sorted descending prefix that grows in doubling blocks (the
//!   softmax mass concentrates, so the walk almost always ends inside the
//!   first block).
//! * [`LogitsProcessor::sample`] — the legacy `&[bool]` mask signature,
//!   kept for tests and simple callers; it materializes the mask as
//!   `-inf` writes and runs the same fused core.
//!
//! Determinism contract: a stochastic sample consumes exactly one RNG
//! draw; candidates are collected in ascending token order; all ordering
//! comparisons use a total order (probability descending, token id
//! ascending on ties). The property tests in `sampler::tests` hold the
//! fused core token-for-token equal to a naive full-sort reference
//! implementation of the same spec.
//!
//! `top_logprobs` reporting still needs the full distribution, so the
//! logprobs path falls back to materialized masks + per-report
//! allocations; that path is per-request opt-in and off the default hot
//! path.

use super::Pcg32;
use crate::grammar::TokenBitmask;
use std::cmp::Ordering;
use std::collections::HashMap;

/// Log-probability record for one sampled token (OpenAI `logprobs`).
#[derive(Clone, Debug, PartialEq)]
pub struct TokenLogprob {
    pub token: u32,
    pub logprob: f32,
    /// The `top_logprobs` most likely alternatives at this position.
    pub top: Vec<(u32, f32)>,
}

/// Per-request sampling controls (OpenAI-style names and semantics).
#[derive(Clone, Debug)]
pub struct SamplingParams {
    /// 0.0 => greedy argmax.
    pub temperature: f32,
    /// Nucleus sampling threshold in (0, 1]; 1.0 disables.
    pub top_p: f32,
    /// Keep only the k most likely tokens; 0 disables.
    pub top_k: usize,
    /// Drop tokens below min_p * max_prob; 0.0 disables.
    pub min_p: f32,
    /// > 1.0 penalizes tokens already generated (multiplicative, CTRL-style).
    pub repetition_penalty: f32,
    /// Additive penalty on any token that has appeared (OpenAI presence).
    pub presence_penalty: f32,
    /// Additive penalty scaled by occurrence count (OpenAI frequency).
    pub frequency_penalty: f32,
    /// token id -> additive bias in [-100, 100].
    pub logit_bias: HashMap<u32, f32>,
    /// RNG seed (None => engine picks one per request).
    pub seed: Option<u64>,
    /// Return per-token log-probabilities (OpenAI `logprobs`).
    pub logprobs: bool,
    /// Number of top alternatives per position (OpenAI `top_logprobs`).
    pub top_logprobs: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self {
            temperature: 1.0,
            top_p: 1.0,
            top_k: 0,
            min_p: 0.0,
            repetition_penalty: 1.0,
            presence_penalty: 0.0,
            frequency_penalty: 0.0,
            logit_bias: HashMap::new(),
            seed: None,
            logprobs: false,
            top_logprobs: 0,
        }
    }
}

impl SamplingParams {
    /// Deterministic argmax decoding (`temperature == 0`).
    pub fn greedy() -> Self {
        Self { temperature: 0.0, ..Self::default() }
    }

    /// Validate ranges (the API layer surfaces these as 400s).
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=2.0).contains(&self.temperature) {
            return Err(format!("temperature {} not in [0, 2]", self.temperature));
        }
        if !(0.0..=1.0).contains(&self.top_p) || self.top_p == 0.0 {
            return Err(format!("top_p {} not in (0, 1]", self.top_p));
        }
        if !(0.0..=1.0).contains(&self.min_p) {
            return Err(format!("min_p {} not in [0, 1]", self.min_p));
        }
        if !(-2.0..=2.0).contains(&self.presence_penalty) {
            return Err(format!("presence_penalty {} not in [-2, 2]", self.presence_penalty));
        }
        if !(-2.0..=2.0).contains(&self.frequency_penalty) {
            return Err(format!("frequency_penalty {} not in [-2, 2]", self.frequency_penalty));
        }
        if self.repetition_penalty <= 0.0 {
            return Err("repetition_penalty must be > 0".into());
        }
        for (&t, &b) in &self.logit_bias {
            if !(-100.0..=100.0).contains(&b) {
                return Err(format!("logit_bias[{t}] = {b} not in [-100, 100]"));
            }
        }
        if self.top_logprobs > 20 {
            return Err(format!("top_logprobs {} > 20", self.top_logprobs));
        }
        Ok(())
    }
}

/// Total order over candidates: unnormalized probability descending,
/// token id ascending on ties. Using a total order keeps partial
/// selection and full sorting interchangeable (same kept set, same walk
/// order) even when probabilities collide.
#[inline]
fn cmp_desc(a: &(u32, f32), b: &(u32, f32)) -> Ordering {
    b.1.partial_cmp(&a.1).unwrap_or(Ordering::Equal).then_with(|| a.0.cmp(&b.0))
}

/// Reusable candidate buffers for the fused sampling core.
///
/// Every buffer is cleared at the start of the pass that uses it, so a
/// single instance can serve *any* number of processors — the engine
/// keeps one per step loop and threads it through every decode row and
/// speculative verify row (`[batch, vocab]` sampling shares one
/// allocation instead of one per sequence). Each [`LogitsProcessor`]
/// also owns one for the standalone entry points.
#[derive(Default)]
pub struct SampleScratch {
    /// Candidate scratch: holds `(token, scaled logit)` during
    /// collection, `(token, unnormalized prob)` afterwards.
    cands: Vec<(u32, f32)>,
    /// Token-id scratch for the `top_logprobs` report.
    idx: Vec<u32>,
    /// `allow_extra` folded into per-word OR overlays, sorted by word
    /// index, so the mask-word loop pays O(1) amortized instead of
    /// rescanning the extras for every word.
    extra: Vec<(usize, u64)>,
}

impl SampleScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Stateful per-sequence processor: tracks occurrence counts for the
/// penalty terms and owns the request RNG.
pub struct LogitsProcessor {
    params: SamplingParams,
    rng: Pcg32,
    counts: HashMap<u32, u32>,
    /// Scratch for the standalone entry points (the decode hot path makes
    /// no steady-state allocations); the `_with` variants take a shared
    /// one instead.
    scratch: SampleScratch,
}

impl LogitsProcessor {
    /// A per-sequence processor; `fallback_seed` seeds the RNG when the
    /// request did not pin [`SamplingParams::seed`].
    pub fn new(params: SamplingParams, fallback_seed: u64) -> Self {
        let seed = params.seed.unwrap_or(fallback_seed);
        Self {
            params,
            rng: Pcg32::new(seed),
            counts: HashMap::new(),
            scratch: SampleScratch::new(),
        }
    }

    /// The request's sampling controls.
    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Record a token that entered the context (prompt or generated) so
    /// penalties see it.
    pub fn observe(&mut self, token: u32) {
        *self.counts.entry(token).or_insert(0) += 1;
    }

    /// Apply penalties + bias in place (steps 1-2 of the pipeline). Cost is
    /// O(distinct observed tokens + bias entries), not O(vocab).
    pub fn apply_penalties(&self, logits: &mut [f32]) {
        let p = &self.params;
        if p.repetition_penalty != 1.0 || p.presence_penalty != 0.0 || p.frequency_penalty != 0.0
        {
            for (&tok, &count) in &self.counts {
                let Some(l) = logits.get_mut(tok as usize) else { continue };
                if p.repetition_penalty != 1.0 {
                    *l = if *l > 0.0 { *l / p.repetition_penalty } else { *l * p.repetition_penalty };
                }
                *l -= p.presence_penalty;
                *l -= p.frequency_penalty * count as f32;
            }
        }
        for (&tok, &bias) in &p.logit_bias {
            if let Some(l) = logits.get_mut(tok as usize) {
                *l += bias;
            }
        }
    }

    /// Legacy pipeline entry: `mask` as unpacked bools, banned tokens
    /// materialized as `-inf` writes. Same fused core as `sample_masked`.
    pub fn sample(&mut self, logits: &mut [f32], mask: Option<&[bool]>) -> u32 {
        self.apply_penalties(logits);
        // Fallback for a degenerate (fully-masking) grammar state: the
        // pre-mask argmax, so generation still makes progress.
        let mut fallback = None;
        if let Some(mask) = mask {
            debug_assert_eq!(mask.len(), logits.len());
            if !mask.iter().any(|&ok| ok) {
                fallback = Some(argmax(logits));
            }
            for (l, &ok) in logits.iter_mut().zip(mask) {
                if !ok {
                    *l = f32::NEG_INFINITY;
                }
            }
        }
        let token = match fallback {
            Some(t) => t,
            None => pick(&self.params, &mut self.rng, &mut self.scratch, logits, None, &[]),
        };
        self.observe(token);
        token
    }

    /// Hot-path pipeline entry: penalties + packed grammar mask +
    /// temperature + truncation + draw, fused over one pass of the logits
    /// row. `allow_extra` lists tokens permitted in addition to the mask
    /// (the engine's EOS allowance when the derivation is accepting) —
    /// this replaces the old copy-the-mask-to-set-EOS step, so cache hits
    /// stay O(1). Does not write `-inf` into `logits`.
    pub fn sample_masked(
        &mut self,
        logits: &mut [f32],
        mask: Option<&TokenBitmask>,
        allow_extra: &[u32],
    ) -> u32 {
        self.apply_penalties(logits);
        let token = pick(&self.params, &mut self.rng, &mut self.scratch, logits, mask, allow_extra);
        self.observe(token);
        token
    }

    /// [`Self::sample_masked`] with caller-provided scratch, so a batch
    /// of rows (or a speculative verify run) shares one set of candidate
    /// buffers across all its processors.
    pub fn sample_masked_with(
        &mut self,
        scratch: &mut SampleScratch,
        logits: &mut [f32],
        mask: Option<&TokenBitmask>,
        allow_extra: &[u32],
    ) -> u32 {
        self.apply_penalties(logits);
        let token = pick(&self.params, &mut self.rng, scratch, logits, mask, allow_extra);
        self.observe(token);
        token
    }

    /// Like `sample_with_logprobs`, but with the packed mask + EOS
    /// allowance of `sample_masked`. When `logprobs` is off this is the
    /// allocation-free fused path; when on, it falls back to the
    /// materialized-mask slow path (the report needs the full masked
    /// distribution anyway).
    pub fn sample_with_logprobs_masked(
        &mut self,
        logits: &mut [f32],
        mask: Option<&TokenBitmask>,
        allow_extra: &[u32],
    ) -> (u32, Option<TokenLogprob>) {
        if !self.params.logprobs {
            return (self.sample_masked(logits, mask, allow_extra), None);
        }
        self.sample_with_logprobs_masked_slow(logits, mask, allow_extra)
    }

    /// [`Self::sample_with_logprobs_masked`] with caller-provided scratch
    /// for the hot (no-logprobs) path; the logprobs report path allocates
    /// regardless, so it keeps using the processor's own buffers.
    pub fn sample_with_logprobs_masked_with(
        &mut self,
        scratch: &mut SampleScratch,
        logits: &mut [f32],
        mask: Option<&TokenBitmask>,
        allow_extra: &[u32],
    ) -> (u32, Option<TokenLogprob>) {
        if !self.params.logprobs {
            return (self.sample_masked_with(scratch, logits, mask, allow_extra), None);
        }
        self.sample_with_logprobs_masked_slow(logits, mask, allow_extra)
    }

    fn sample_with_logprobs_masked_slow(
        &mut self,
        logits: &mut [f32],
        mask: Option<&TokenBitmask>,
        allow_extra: &[u32],
    ) -> (u32, Option<TokenLogprob>) {
        match mask {
            None => self.sample_with_logprobs(logits, None),
            Some(m) => {
                let mut bools = m.to_bools();
                for &e in allow_extra {
                    if let Some(slot) = bools.get_mut(e as usize) {
                        *slot = true;
                    }
                }
                self.sample_with_logprobs(logits, Some(&bools))
            }
        }
    }

    /// Like `sample`, additionally returning the sampled token's logprob
    /// and the top-`top_logprobs` alternatives, computed over the final
    /// (post-penalty, post-mask, temperature-scaled) distribution —
    /// OpenAI semantics.
    pub fn sample_with_logprobs(
        &mut self,
        logits: &mut [f32],
        mask: Option<&[bool]>,
    ) -> (u32, Option<TokenLogprob>) {
        let token = self.sample(logits, mask);
        if !self.params.logprobs {
            return (token, None);
        }
        // `logits` now holds the post-penalty/mask values (sample mutates
        // in place). Log-softmax at the effective temperature.
        let inv_t = if self.params.temperature > 0.0 { 1.0 / self.params.temperature } else { 1.0 };
        let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut log_z = 0.0f32;
        for &l in logits.iter() {
            if l.is_finite() {
                log_z += ((l - m) * inv_t).exp();
            }
        }
        let log_z = log_z.ln();
        let lp = |i: u32| -> f32 {
            let l = logits[i as usize];
            if l.is_finite() { (l - m) * inv_t - log_z } else { f32::NEG_INFINITY }
        };
        let mut top: Vec<(u32, f32)> = Vec::new();
        let k_req = self.params.top_logprobs;
        if k_req > 0 {
            let idx = &mut self.scratch.idx;
            idx.clear();
            idx.extend((0..logits.len() as u32).filter(|&i| logits[i as usize].is_finite()));
            let k = k_req.min(idx.len());
            if k > 0 {
                idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
                    logits[b as usize]
                        .partial_cmp(&logits[a as usize])
                        .unwrap_or(Ordering::Equal)
                });
                idx.truncate(k);
                idx.sort_unstable_by(|&a, &b| {
                    logits[b as usize]
                        .partial_cmp(&logits[a as usize])
                        .unwrap_or(Ordering::Equal)
                });
                top = idx.iter().map(|&i| (i, lp(i))).collect();
            }
        }
        (token, Some(TokenLogprob { token, logprob: lp(token), top }))
    }

}

// -- fused core -------------------------------------------------------------

/// Select one token from `logits` under `mask` + `allow_extra`.
/// Candidates are collected in ascending token order; greedy takes an
/// argmax over them, otherwise `sample_stochastic_fused` draws. A free
/// function over disjoint processor parts so callers can thread in a
/// shared [`SampleScratch`] alongside the per-request params/RNG.
fn pick(
    params: &SamplingParams,
    rng: &mut Pcg32,
    scratch: &mut SampleScratch,
    logits: &[f32],
    mask: Option<&TokenBitmask>,
    extra: &[u32],
) -> u32 {
    let greedy = params.temperature == 0.0;
    if greedy && mask.is_none() {
        // No collection needed: plain argmax over the row.
        return argmax(logits);
    }
    let inv_t = if greedy { 1.0 } else { 1.0 / params.temperature };

    scratch.cands.clear();
    match mask {
        Some(m) => {
            debug_assert_eq!(m.len(), logits.len());
            // Fold the (tiny) extra allowance into per-word OR
            // overlays once, sorted by word, so the word loop below
            // consumes them with a forward cursor instead of scanning
            // `extra` per word.
            scratch.extra.clear();
            for &e in extra {
                let e = e as usize;
                if e < logits.len() {
                    let (wi, bit) = (e / 64, 1u64 << (e % 64));
                    match scratch.extra.iter_mut().find(|(w, _)| *w == wi) {
                        Some((_, bits)) => *bits |= bit,
                        None => scratch.extra.push((wi, bit)),
                    }
                }
            }
            scratch.extra.sort_unstable_by_key(|&(w, _)| w);
            let mut ei = 0usize;
            for (wi, &w0) in m.words().iter().enumerate() {
                let mut w = w0;
                if ei < scratch.extra.len() && scratch.extra[ei].0 == wi {
                    w |= scratch.extra[ei].1;
                    ei += 1;
                }
                if w == 0 {
                    continue; // 64 banned tokens skipped per test
                }
                let base = wi * 64;
                while w != 0 {
                    let i = base + w.trailing_zeros() as usize;
                    w &= w - 1;
                    // Test the *scaled* value: a tiny (but valid)
                    // temperature can overflow finite logits to ±inf,
                    // which would poison step 1 with inf - inf = NaN.
                    let s = logits[i] * inv_t;
                    if s.is_finite() {
                        scratch.cands.push((i as u32, s));
                    }
                }
            }
        }
        None => {
            for (i, &l) in logits.iter().enumerate() {
                let s = l * inv_t;
                if s.is_finite() {
                    scratch.cands.push((i as u32, s));
                }
            }
        }
    }
    if scratch.cands.is_empty() {
        // Degenerate state (fully masked, or every scaled logit
        // non-finite — e.g. temperature small enough to overflow):
        // argmax over the raw row, which is also the temperature -> 0
        // limit of the distribution.
        return argmax(logits);
    }
    if greedy {
        let mut best = scratch.cands[0];
        for &(i, l) in &scratch.cands[1..] {
            if l > best.1 {
                best = (i, l);
            }
        }
        return best.0;
    }
    sample_stochastic_fused(params, rng, &mut scratch.cands)
}

/// Stochastic draw over the candidates in `cands`.
///
/// Spec (mirrored exactly by the reference implementation in the
/// property tests):
///   1. values become unnormalized probs `e = exp(l - max_l)`
///      (so `e_max == 1.0` exactly);
///   2. top-k keeps the k largest under the `cmp_desc` total order
///      (partial selection + small sort instead of a full sort);
///   3. min-p keeps `e >= min_p` (threshold filter — equivalent to the
///      classic normalized formulation because `e_max == 1`);
///   4. `total` = sum of kept `e` in the array's current order;
///   5. top-p keeps the smallest `cmp_desc`-descending prefix with
///      cumulative mass `>= top_p * total` (lazy descending walk);
///   6. the inverse-CDF draw walks the kept set in the same descending
///      order with target `r * kept_total`.
fn sample_stochastic_fused(
    params: &SamplingParams,
    rng: &mut Pcg32,
    cands: &mut Vec<(u32, f32)>,
) -> u32 {
    let top_k = params.top_k;
    let top_p = params.top_p;
    let min_p = params.min_p;

    // 1. scaled logits -> unnormalized probs.
    let max_l = cands.iter().fold(f32::NEG_INFINITY, |a, &(_, l)| a.max(l));
    for c in cands.iter_mut() {
        c.1 = (c.1 - max_l).exp();
    }

    // 2. top-k: partial selection, then sort the kept block so the
    // array order is descending (k is user-small; sorting it is cheap
    // and makes min-p/top-p prefix logic trivially order-correct).
    let mut sorted_len = 0usize;
    if top_k > 0 && top_k < cands.len() {
        cands.select_nth_unstable_by(top_k - 1, cmp_desc);
        cands.truncate(top_k);
        cands.sort_unstable_by(cmp_desc);
        sorted_len = cands.len();
    }

    // 3. min-p threshold filter. Clamped to 1.0 so the max candidate
    // (e == 1.0 exactly) always survives and the kept set can never
    // empty — even for out-of-range params that bypassed validate().
    if min_p > 0.0 {
        let floor = min_p.min(1.0);
        cands.retain(|&(_, e)| e >= floor);
        sorted_len = sorted_len.min(cands.len());
    }

    // 4. total mass in array order.
    let total: f32 = cands.iter().map(|&(_, e)| e).sum();
    let mut kept_total = total;

    // 5. top-p: walk the descending order lazily until the nucleus is
    // covered; everything past the cut is dropped.
    if top_p < 1.0 {
        let target = top_p * total;
        let mut cum = 0.0f32;
        let mut i = 0usize;
        let mut kept = cands.len();
        'nucleus: while i < cands.len() {
            if i >= sorted_len {
                sorted_len = grow_sorted_prefix(cands, sorted_len);
            }
            while i < sorted_len {
                cum += cands[i].1;
                i += 1;
                if cum >= target {
                    kept = i;
                    kept_total = cum;
                    break 'nucleus;
                }
            }
        }
        cands.truncate(kept);
        sorted_len = sorted_len.min(kept);
    }

    // 6. inverse-CDF draw in descending order (the mass concentrates
    // up front, so this rarely grows the sorted prefix further).
    let r = rng.f32();
    let target = r * kept_total;
    let mut cum = 0.0f32;
    let mut i = 0usize;
    while i < cands.len() {
        if i >= sorted_len {
            sorted_len = grow_sorted_prefix(cands, sorted_len);
        }
        while i < sorted_len {
            cum += cands[i].1;
            if target < cum {
                return cands[i].0;
            }
            i += 1;
        }
    }
    // Numerical fallthrough (rounding left target >= cum at the end).
    cands[cands.len() - 1].0
}

/// Grow the `cmp_desc`-sorted prefix of `v` by (at least) a doubling step:
/// select the next block out of the unsorted tail, then sort just that
/// block. Every element of the tail orders after the existing prefix
/// (established by the previous selection), so prefix order stays global.
fn grow_sorted_prefix(v: &mut [(u32, f32)], sorted_len: usize) -> usize {
    let n = v.len();
    if sorted_len >= n {
        return sorted_len;
    }
    let new_len = n.min((sorted_len * 2).max(64));
    let need = new_len - sorted_len;
    let tail = &mut v[sorted_len..];
    if need < tail.len() {
        tail.select_nth_unstable_by(need - 1, cmp_desc);
    }
    tail[..need].sort_unstable_by(cmp_desc);
    new_len
}

fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        if l > best_v {
            best_v = l;
            best = i;
        }
    }
    best as u32
}
