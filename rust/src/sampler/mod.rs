//! Sampling: the logits-to-token pipeline.
//!
//! WebLLM implements OpenAI-compatible sampling controls in the worker
//! engine (temperature, top_p, penalties, logit_bias, seed); this module
//! is that pipeline, applied in the same order MLC-LLM uses:
//!
//!   1. repetition / presence / frequency penalties
//!   2. logit bias
//!   3. grammar mask (structured generation, `crate::grammar`)
//!   4. temperature
//!   5. top-k / top-p / min-p truncation
//!   6. sample (seeded PCG) or argmax when temperature == 0
//!
//! The decode hot path enters through `LogitsProcessor::sample_masked`,
//! which fuses steps 3-6 into one pass over the logits row driven by the
//! grammar's packed `TokenBitmask` (zero mask words skip 64 banned tokens
//! at a time) and replaces the full descending sort with partial
//! selection; all scratch lives in reusable per-processor buffers. See
//! `logits` module docs for the determinism contract.

mod logits;
mod rng;

pub use logits::{LogitsProcessor, SampleScratch, SamplingParams, TokenLogprob};
pub use rng::{branch_seed, Pcg32};

#[cfg(test)]
mod tests;
