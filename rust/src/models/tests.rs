use super::*;
use crate::json::parse;

fn artifacts() -> Option<std::path::PathBuf> {
    let p = crate::artifacts_dir();
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn manifest_loads_and_validates() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.models.contains_key("tiny-2m"));
    assert_eq!(m.group_size, 64);
    assert_eq!(m.pack, 8);
    let rec = m.model("tiny-2m").unwrap();
    assert_eq!(rec.config.n_layers, 2);
    assert_eq!(rec.config.max_pages_per_seq(), 16);
    assert!(rec.prefill.contains_key(&16));
    assert!(rec.decode.contains_key(&1));
}

#[test]
fn unknown_model_is_helpful() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let err = m.model("gpt-17").unwrap_err();
    assert!(err.contains("tiny-2m"), "{err}");
}

#[test]
fn weight_file_validates_layout() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    for name in ["tiny-2m", "phi-web-38m"] {
        let rec = m.model(name).unwrap();
        let f = WeightFile::load(rec).unwrap();
        // embed is f32[V, D]; spot check a plausible float magnitude
        let e = &rec.weights[0];
        assert_eq!(e.spec.name, "embed");
        let b = f.bytes(e);
        let x = f32::from_le_bytes(b[0..4].try_into().unwrap());
        assert!(x.abs() < 1.0, "embed[0] = {x}");
    }
}

#[test]
fn weight_file_rejects_corrupt_manifest() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let mut rec = m.model("tiny-2m").unwrap().clone();
    rec.weights[0].nbytes += 4; // size mismatch vs spec
    assert!(WeightFile::load(&rec).is_err());
}

#[test]
fn config_pickers() {
    let v = parse(r#"{
        "name":"x","vocab_size":4096,"d_model":128,"n_layers":2,"n_heads":4,
        "n_kv_heads":2,"head_dim":32,"ffn_dim":256,"rope_theta":10000.0,
        "norm_eps":1e-5,"page_size":8,"num_pages":32,"max_seq_len":64,
        "prefill_chunks":[16,32],"decode_batches":[1,2,4],"param_count":1}"#).unwrap();
    let c = ModelConfig::from_json(&v).unwrap();
    assert_eq!(c.pick_chunk(9), Some(16));
    assert_eq!(c.pick_chunk(17), Some(32));
    assert_eq!(c.pick_chunk(33), None);
    assert_eq!(c.pick_batch(1), Some(1));
    assert_eq!(c.pick_batch(3), Some(4));
    assert_eq!(c.pick_batch(5), None);
    assert_eq!(c.max_prefill_chunk(), 32);
    assert_eq!(c.min_prefill_chunk(), 16);
}

#[test]
fn chunked_prefill_step_policy() {
    let v = parse(r#"{
        "name":"x","vocab_size":4096,"d_model":128,"n_layers":2,"n_heads":4,
        "n_kv_heads":2,"head_dim":32,"ffn_dim":256,"rope_theta":10000.0,
        "norm_eps":1e-5,"page_size":8,"num_pages":32,"max_seq_len":64,
        "prefill_chunks":[16,32],"decode_batches":[1,2,4],"param_count":1}"#).unwrap();
    let c = ModelConfig::from_json(&v).unwrap();

    // Nothing left: no chunk.
    assert_eq!(c.next_prefill_tokens(0, 16), None);
    // Budget below the menu clamps up to the smallest compiled chunk.
    assert_eq!(c.next_prefill_tokens(100, 1), Some((16, 16)));
    // Budget above the menu clamps down to the largest.
    assert_eq!(c.next_prefill_tokens(100, usize::MAX), Some((32, 32)));
    // In-menu budget is honored exactly.
    assert_eq!(c.next_prefill_tokens(100, 16), Some((16, 16)));
    // The tail takes the smallest chunk that fits it.
    assert_eq!(c.next_prefill_tokens(5, 32), Some((5, 16)));
    assert_eq!(c.next_prefill_tokens(20, 32), Some((20, 32)));
    // A between-menu budget rounds DOWN to a full compiled chunk — it
    // never pays a larger executable to advance fewer positions.
    assert_eq!(c.next_prefill_tokens(100, 20), Some((16, 16)));
    assert_eq!(c.next_prefill_tokens(100, 31), Some((16, 16)));
}

#[test]
fn adaptive_prefill_budget_policy() {
    let v = parse(r#"{
        "name":"x","vocab_size":4096,"d_model":128,"n_layers":2,"n_heads":4,
        "n_kv_heads":2,"head_dim":32,"ffn_dim":256,"rope_theta":10000.0,
        "norm_eps":1e-5,"page_size":8,"num_pages":32,"max_seq_len":64,
        "prefill_chunks":[16,32],"decode_batches":[1,2,4],"param_count":1}"#).unwrap();
    let c = ModelConfig::from_json(&v).unwrap();

    // Idle: nobody to stall, spend the whole menu.
    assert_eq!(c.adaptive_prefill_budget(32, 0), usize::MAX);
    assert_eq!(c.next_prefill_tokens(100, c.adaptive_prefill_budget(32, 0)), Some((32, 32)));
    // One decode row: the configured budget applies as-is.
    assert_eq!(c.adaptive_prefill_budget(32, 1), 32);
    // Budget halves per doubling of the decode batch...
    assert_eq!(c.adaptive_prefill_budget(32, 2), 16);
    assert_eq!(c.adaptive_prefill_budget(32, 3), 8);
    assert_eq!(c.adaptive_prefill_budget(32, 4), 8);
    // ...and the menu fallback keeps the result executable (floor =
    // smallest compiled chunk), never zero.
    assert_eq!(c.next_prefill_tokens(100, c.adaptive_prefill_budget(32, 4)), Some((16, 16)));
    assert_eq!(c.next_prefill_tokens(100, c.adaptive_prefill_budget(1, 4)), Some((16, 16)));
}

#[test]
fn config_missing_field_errors() {
    let v = parse(r#"{"name":"x"}"#).unwrap();
    assert!(ModelConfig::from_json(&v).is_err());
}
