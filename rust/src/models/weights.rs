//! Quantized weight shard loading + layout validation.

use super::registry::{ModelRecord, WeightEntry};

/// The raw weights_q4.bin contents with validated entry bounds.
pub struct WeightFile {
    data: Vec<u8>,
}

impl WeightFile {
    pub fn load(record: &ModelRecord) -> Result<Self, String> {
        let data = std::fs::read(&record.weights_bin)
            .map_err(|e| format!("cannot read {}: {e}", record.weights_bin.display()))?;
        let f = Self { data };
        f.validate(record)?;
        Ok(f)
    }

    fn validate(&self, record: &ModelRecord) -> Result<(), String> {
        let mut prev_end = 0usize;
        for e in &record.weights {
            if e.offset % 64 != 0 {
                return Err(format!("weight '{}' misaligned offset {}", e.spec.name, e.offset));
            }
            if e.offset < prev_end {
                return Err(format!("weight '{}' overlaps previous", e.spec.name));
            }
            if e.nbytes != e.spec.byte_len() {
                return Err(format!(
                    "weight '{}' size {} != spec {}",
                    e.spec.name,
                    e.nbytes,
                    e.spec.byte_len()
                ));
            }
            if e.offset + e.nbytes > self.data.len() {
                return Err(format!("weight '{}' out of file bounds", e.spec.name));
            }
            prev_end = e.offset + e.nbytes;
        }
        if prev_end != self.data.len() {
            return Err(format!(
                "weight file has {} trailing bytes",
                self.data.len() - prev_end
            ));
        }
        Ok(())
    }

    /// Raw little-endian bytes for one weight tensor.
    pub fn bytes(&self, e: &WeightEntry) -> &[u8] {
        &self.data[e.offset..e.offset + e.nbytes]
    }

    pub fn total_bytes(&self) -> usize {
        self.data.len()
    }
}
