//! Architecture config (mirrors python/compile/configs.py ModelConfig).

use crate::json::Value;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub page_size: usize,
    pub num_pages: usize,
    pub max_seq_len: usize,
    pub prefill_chunks: Vec<usize>,
    pub decode_batches: Vec<usize>,
    pub param_count: u64,
}

impl ModelConfig {
    pub fn from_json(v: &Value) -> Result<Self, String> {
        let get = |k: &str| v.get(k).ok_or_else(|| format!("config missing '{k}'"));
        let usize_of = |k: &str| -> Result<usize, String> {
            get(k)?.as_usize().ok_or_else(|| format!("config '{k}' not a usize"))
        };
        let list_of = |k: &str| -> Result<Vec<usize>, String> {
            get(k)?
                .as_array()
                .ok_or_else(|| format!("config '{k}' not a list"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| format!("bad entry in '{k}'")))
                .collect()
        };
        Ok(Self {
            name: get("name")?.as_str().ok_or("name not a string")?.to_string(),
            vocab_size: usize_of("vocab_size")?,
            d_model: usize_of("d_model")?,
            n_layers: usize_of("n_layers")?,
            n_heads: usize_of("n_heads")?,
            n_kv_heads: usize_of("n_kv_heads")?,
            head_dim: usize_of("head_dim")?,
            ffn_dim: usize_of("ffn_dim")?,
            rope_theta: get("rope_theta")?.as_f64().ok_or("rope_theta not a number")?,
            norm_eps: get("norm_eps")?.as_f64().ok_or("norm_eps not a number")?,
            page_size: usize_of("page_size")?,
            num_pages: usize_of("num_pages")?,
            max_seq_len: usize_of("max_seq_len")?,
            prefill_chunks: list_of("prefill_chunks")?,
            decode_batches: list_of("decode_batches")?,
            param_count: get("param_count")?.as_u64().unwrap_or(0),
        })
    }

    pub fn max_pages_per_seq(&self) -> usize {
        self.max_seq_len / self.page_size
    }

    /// Largest chunk the compiled prefill menu holds — the most prompt
    /// tokens one prefill step can process (prompts longer than this are
    /// fed in multiple positioned chunks, see `next_prefill_tokens`).
    pub fn max_prefill_chunk(&self) -> usize {
        self.prefill_chunks.iter().copied().max().unwrap_or(0)
    }

    /// Smallest compiled prefill chunk.
    pub fn min_prefill_chunk(&self) -> usize {
        self.prefill_chunks.iter().copied().min().unwrap_or(0)
    }

    /// Largest compiled decode batch.
    pub fn max_decode_batch(&self) -> usize {
        self.decode_batches.iter().copied().max().unwrap_or(1)
    }

    /// Smallest compiled chunk that fits `n` prompt tokens.
    pub fn pick_chunk(&self, n: usize) -> Option<usize> {
        self.prefill_chunks.iter().copied().filter(|&c| c >= n).min()
    }

    /// Smallest compiled batch that fits `n` live sequences.
    pub fn pick_batch(&self, n: usize) -> Option<usize> {
        self.decode_batches.iter().copied().filter(|&b| b >= n).min()
    }

    /// The chunked-prefill step policy: given `remaining` uncomputed
    /// prompt tokens and the engine's per-step token `budget`, how many
    /// tokens the next prefill chunk should carry and which compiled
    /// chunk executable runs it. Returns `None` when nothing remains.
    ///
    /// The per-step cap is the **largest compiled chunk ≤ budget** —
    /// never `budget` itself — so a between-menu budget (say 20 on a
    /// [16, 32, 64] menu) runs a full 16-token chunk rather than paying
    /// a 32-token executable to advance 20 positions. Budgets below the
    /// whole menu fall back to the smallest chunk (a smaller executable
    /// doesn't exist), budgets above it to the largest (the prompt just
    /// takes more steps) — any value is safe, and the knob only trades
    /// TTFT (big chunks, prompt done sooner) against decode stall / ITL
    /// (small chunks, running sequences wait less per step). Only the
    /// prompt's final slice may under-fill its executable.
    pub fn next_prefill_tokens(&self, remaining: usize, budget: usize) -> Option<(usize, usize)> {
        if remaining == 0 || self.prefill_chunks.is_empty() {
            return None;
        }
        let cap = self
            .prefill_chunks
            .iter()
            .copied()
            .filter(|&c| c <= budget)
            .max()
            .unwrap_or_else(|| self.min_prefill_chunk());
        let n = remaining.min(cap);
        let chunk = self.pick_chunk(n).expect("n <= max_prefill_chunk");
        Some((n, chunk))
    }

    /// Sarathi-style adaptive prefill budget: scale the configured
    /// `budget` by the current decode load before it is clamped to the
    /// compiled menu by [`Self::next_prefill_tokens`].
    ///
    /// With no decode rows there is nobody to stall — spend the whole
    /// menu (`usize::MAX`; the clamp caps it at the largest compiled
    /// chunk) and finish the prompt in as few steps as possible (TTFT).
    /// With `decode_rows >= 1` the budget shrinks as rows pile up —
    /// halved per doubling of the batch (`budget / next_power_of_two`)
    /// — bounding the per-step stall every running sequence pays (ITL).
    /// The menu fallback in `next_prefill_tokens` keeps any result
    /// executable, so the smallest compiled chunk is the floor.
    pub fn adaptive_prefill_budget(&self, budget: usize, decode_rows: usize) -> usize {
        if decode_rows == 0 {
            usize::MAX
        } else {
            budget / decode_rows.next_power_of_two()
        }
    }
}
