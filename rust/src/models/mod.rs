//! Model records, configs and weight artifacts.
//!
//! The analog of WebLLM's `prebuiltAppConfig` + `mlc-chat-config.json`:
//! the manifest (written by `python/compile/aot.py`) lists every model
//! the engine can load, its architecture config, quantized weight shards,
//! and the AOT executables per (phase, static shape).

mod config;
mod reference;
mod registry;
mod weights;

pub use config::ModelConfig;
pub use reference::{
    reference_model_config, reference_model_names, reference_tokenizer, REFERENCE_VOCAB_SIZE,
};
pub use registry::{ExeEntry, Manifest, ModelRecord, TensorSpec};
pub use weights::WeightFile;

#[cfg(test)]
mod tests;
