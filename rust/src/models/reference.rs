//! Built-in reference models: registry entries that need no artifacts.
//!
//! The XLA path loads models from `artifacts/manifest.json` (weights,
//! HLO executables, trained tokenizer). The reference path ships its
//! registry in code: a couple of `tiny-ref*` configs plus a synthetic
//! byte-level tokenizer, so `EngineConfig::reference(&["tiny-ref"])`
//! stands up a full engine — scheduler, paged KV, grammar, streaming,
//! HTTP — on any machine, which is what lets CI run the entire e2e
//! suite without `make artifacts`.

use super::ModelConfig;
use crate::tokenizer::Tokenizer;

/// Vocabulary size shared by every reference model and the reference
/// tokenizer (8 specials + 256 bytes + a few merges + unused tail).
pub const REFERENCE_VOCAB_SIZE: usize = 300;

/// Names the reference registry can load.
pub fn reference_model_names() -> Vec<&'static str> {
    vec!["tiny-ref", "tiny-ref-b"]
}

/// Registry lookup. `tiny-ref` and `tiny-ref-b` differ in depth and
/// pool size (and, through the name-mixed seed, in every logit), so
/// multi-model scenarios observe genuinely distinct models.
pub fn reference_model_config(name: &str) -> Result<ModelConfig, String> {
    let (n_layers, num_pages) = match name {
        "tiny-ref" => (2, 64),
        "tiny-ref-b" => (3, 48),
        _ => {
            return Err(format!(
                "unknown model '{name}'; reference registry has: {:?}",
                reference_model_names()
            ))
        }
    };
    Ok(ModelConfig {
        name: name.to_string(),
        vocab_size: REFERENCE_VOCAB_SIZE,
        d_model: 32,
        n_layers,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 16,
        ffn_dim: 64,
        rope_theta: 10_000.0,
        norm_eps: 1e-5,
        page_size: 8,
        num_pages,
        max_seq_len: 128,
        prefill_chunks: vec![16, 32, 64],
        decode_batches: vec![1, 2, 4, 8],
        param_count: 262_144,
    })
}

/// The synthetic byte-level BPE vocabulary every reference model shares:
/// the 8 reserved specials the chat template needs, all 256 bytes, and a
/// few merges so multi-byte tokens exercise the streaming decoder.
pub fn reference_tokenizer() -> Tokenizer {
    let h = 8 + b'h' as u32;
    let e = 8 + b'e' as u32;
    let l = 8 + b'l' as u32;
    let sp = 8 + b' ' as u32;
    let w = 8 + b'w' as u32;
    let json = format!(
        r#"{{
        "vocab_size": {REFERENCE_VOCAB_SIZE},
        "byte_offset": 8,
        "specials": {{"<pad>":0,"<bos>":1,"<eos>":2,"<unk>":3,
                      "<|system|>":4,"<|user|>":5,"<|assistant|>":6,"<|end|>":7}},
        "merges": [[{h},{e}],[{l},{l}],[264,265],[{sp},{w}]]
    }}"#
    );
    let v = crate::json::parse(&json).expect("reference tokenizer json is static");
    Tokenizer::from_json(&v).expect("reference tokenizer vocabulary is static")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_configs_are_consistent() {
        for name in reference_model_names() {
            let mc = reference_model_config(name).unwrap();
            assert_eq!(mc.name, name);
            assert_eq!(mc.vocab_size, REFERENCE_VOCAB_SIZE);
            assert!(mc.max_pages_per_seq() * mc.page_size == mc.max_seq_len);
            assert!(mc.max_prefill_chunk() <= mc.max_seq_len);
            assert!(mc.num_pages >= mc.max_pages_per_seq());
        }
        assert!(reference_model_config("tiny-2m").is_err());
    }

    #[test]
    fn tokenizer_matches_model_vocab() {
        let tok = reference_tokenizer();
        assert_eq!(tok.vocab_size(), REFERENCE_VOCAB_SIZE);
        for name in ["<bos>", "<eos>", "<|system|>", "<|user|>", "<|assistant|>", "<|end|>"] {
            assert!(tok.special_id(name).is_some(), "missing special {name}");
        }
        // Round-trips text, including merged tokens.
        for s in ["hello world", "json: {\"ok\": true}", ""] {
            assert_eq!(tok.decode(&tok.encode(s)), s);
        }
    }
}
