//! Manifest parsing: the model registry the engine loads from.

use super::ModelConfig;
use crate::json::{parse, Value};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// dtype + shape of one tensor in the artifact contract.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "u32" | "i32"
}

impl TensorSpec {
    fn from_json(v: &Value) -> Result<Self, String> {
        Ok(Self {
            name: v.get("name").and_then(Value::as_str).ok_or("spec missing name")?.into(),
            shape: v
                .get("shape")
                .and_then(Value::as_array)
                .ok_or("spec missing shape")?
                .iter()
                .map(|x| x.as_usize().ok_or("bad shape entry"))
                .collect::<Result<_, _>>()?,
            dtype: v.get("dtype").and_then(Value::as_str).ok_or("spec missing dtype")?.into(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.element_count() * 4 // f32/u32/i32 all 4 bytes
    }
}

/// A weight tensor entry in weights_q4.bin.
#[derive(Clone, Debug)]
pub struct WeightEntry {
    pub spec: TensorSpec,
    pub offset: usize,
    pub nbytes: usize,
}

/// One AOT executable (HLO text file) + its phase-specific input specs.
#[derive(Clone, Debug)]
pub struct ExeEntry {
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
}

/// Everything the runtime needs to load one model.
#[derive(Clone, Debug)]
pub struct ModelRecord {
    pub config: ModelConfig,
    pub weights_bin: PathBuf,
    pub weights: Vec<WeightEntry>,
    pub cache: Vec<TensorSpec>,
    /// chunk size -> prefill executable
    pub prefill: BTreeMap<usize, ExeEntry>,
    /// batch size -> decode executable
    pub decode: BTreeMap<usize, ExeEntry>,
}

/// Parsed artifacts/manifest.json.
pub struct Manifest {
    pub root: PathBuf,
    pub group_size: usize,
    pub pack: usize,
    pub tokenizer_path: PathBuf,
    pub models: BTreeMap<String, ModelRecord>,
    /// Micro-bench executables (kernel ablations), name -> entry.
    pub kernel_bench: BTreeMap<String, ExeEntry>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self, String> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let v = parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(artifacts_dir, &v)
    }

    /// Artifact ABI version this runtime speaks. 2 = positioned prefill
    /// (`[ids, start_pos, n, block_table]` executables); older artifacts
    /// would load fine but fail at the first prefill with an opaque
    /// shape/arity error, so version skew is rejected up front.
    pub const ARTIFACT_VERSION: u64 = 2;

    pub fn from_json(root: &Path, v: &Value) -> Result<Self, String> {
        let version = v.get("version").and_then(Value::as_u64).unwrap_or(0);
        if version != Self::ARTIFACT_VERSION {
            return Err(format!(
                "artifact manifest version {version} != {} (this runtime's positioned-prefill \
                 ABI); re-run `make artifacts`",
                Self::ARTIFACT_VERSION
            ));
        }
        let models_v = v.get("models").and_then(Value::as_object).ok_or("manifest missing models")?;
        let mut models = BTreeMap::new();
        for (name, mv) in models_v.iter() {
            models.insert(name.clone(), Self::model_record(root, mv)?);
        }
        let mut kernel_bench = BTreeMap::new();
        if let Some(kb) = v.get("kernel_bench").and_then(Value::as_object) {
            for (name, entry) in kb.iter() {
                let path = root.join(
                    entry.get("path").and_then(Value::as_str).ok_or("kernel_bench missing path")?,
                );
                let inputs = entry
                    .get("inputs")
                    .and_then(Value::as_array)
                    .ok_or("kernel_bench missing inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>, _>>()?;
                kernel_bench.insert(name.clone(), ExeEntry { path, inputs });
            }
        }
        Ok(Self {
            root: root.to_path_buf(),
            group_size: v.get("group_size").and_then(Value::as_usize).ok_or("missing group_size")?,
            pack: v.get("pack").and_then(Value::as_usize).ok_or("missing pack")?,
            tokenizer_path: root.join(
                v.get("tokenizer").and_then(Value::as_str).unwrap_or("tokenizer.json"),
            ),
            models,
            kernel_bench,
        })
    }

    fn model_record(root: &Path, v: &Value) -> Result<ModelRecord, String> {
        let config = ModelConfig::from_json(v.get("config").ok_or("model missing config")?)?;
        let weights = v
            .get("weights")
            .and_then(Value::as_array)
            .ok_or("model missing weights")?
            .iter()
            .map(|w| {
                Ok(WeightEntry {
                    spec: TensorSpec::from_json(w)?,
                    offset: w.get("offset").and_then(Value::as_usize).ok_or("weight missing offset")?,
                    nbytes: w.get("nbytes").and_then(Value::as_usize).ok_or("weight missing nbytes")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let cache = v
            .get("cache")
            .and_then(Value::as_array)
            .ok_or("model missing cache")?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let exe_map = |key: &str| -> Result<BTreeMap<usize, ExeEntry>, String> {
            let mut out = BTreeMap::new();
            let obj = v.get(key).and_then(Value::as_object).ok_or(format!("missing {key}"))?;
            for (size, entry) in obj.iter() {
                let size: usize = size.parse().map_err(|_| format!("bad {key} key '{size}'"))?;
                let path = root.join(
                    entry.get("path").and_then(Value::as_str).ok_or("exe missing path")?,
                );
                let inputs = entry
                    .get("inputs")
                    .and_then(Value::as_array)
                    .ok_or("exe missing inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>, _>>()?;
                out.insert(size, ExeEntry { path, inputs });
            }
            Ok(out)
        };
        Ok(ModelRecord {
            config,
            weights_bin: root.join(
                v.get("weights_bin").and_then(Value::as_str).ok_or("missing weights_bin")?,
            ),
            weights,
            cache,
            prefill: exe_map("prefill")?,
            decode: exe_map("decode")?,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelRecord, String> {
        self.models
            .get(name)
            .ok_or_else(|| {
                let known: Vec<&str> = self.models.keys().map(String::as_str).collect();
                format!("unknown model '{name}'; available: {known:?}")
            })
    }
}
