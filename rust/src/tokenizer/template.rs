//! Chat template rendering.
//!
//! WebLLM renders each model's conversation template before tokenizing
//! (the `mlc-chat-config.json` `conv_template` field); our synthetic
//! models share one template built on the reserved special tokens:
//!
//! ```text
//! <bos><|system|>{system}<|end|><|user|>{user}<|end|><|assistant|>{...}<|end|>
//! ```
//!
//! The assistant turn is left open; generation stops on `<|end|>` / `<eos>`.

use super::Tokenizer;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    System,
    User,
    Assistant,
}

impl Role {
    pub fn from_str(s: &str) -> Option<Role> {
        match s {
            "system" => Some(Role::System),
            "user" => Some(Role::User),
            "assistant" => Some(Role::Assistant),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Role::System => "system",
            Role::User => "user",
            Role::Assistant => "assistant",
        }
    }

    fn tag(&self) -> &'static str {
        match self {
            Role::System => "<|system|>",
            Role::User => "<|user|>",
            Role::Assistant => "<|assistant|>",
        }
    }
}

#[derive(Clone, Debug)]
pub struct ChatMessage {
    pub role: Role,
    pub content: String,
}

impl ChatMessage {
    pub fn new(role: Role, content: impl Into<String>) -> Self {
        Self { role, content: content.into() }
    }
}

/// Render a conversation to prompt token ids, ending with an open
/// assistant turn ready for generation.
pub fn render_chat(tok: &Tokenizer, messages: &[ChatMessage]) -> Vec<u32> {
    let mut text = String::from("<bos>");
    for m in messages {
        text.push_str(m.role.tag());
        text.push_str(&m.content);
        text.push_str("<|end|>");
    }
    text.push_str(Role::Assistant.tag());
    tok.encode_with_specials(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::tests::test_tokenizer;

    #[test]
    fn render_produces_tagged_ids() {
        let tok = test_tokenizer();
        let ids = render_chat(
            &tok,
            &[
                ChatMessage::new(Role::System, "be brief"),
                ChatMessage::new(Role::User, "hi"),
            ],
        );
        let bos = tok.special_id("<bos>").unwrap();
        let sys = tok.special_id("<|system|>").unwrap();
        let user = tok.special_id("<|user|>").unwrap();
        let asst = tok.special_id("<|assistant|>").unwrap();
        let end = tok.special_id("<|end|>").unwrap();
        assert_eq!(ids[0], bos);
        assert_eq!(ids[1], sys);
        assert_eq!(*ids.last().unwrap(), asst);
        assert_eq!(ids.iter().filter(|&&i| i == end).count(), 2);
        assert!(ids.contains(&user));
        // Content bytes survive the trip.
        let text = tok.decode(&ids);
        assert!(text.contains("be brief"));
        assert!(text.contains("hi"));
    }

    #[test]
    fn role_parsing() {
        assert_eq!(Role::from_str("user"), Some(Role::User));
        assert_eq!(Role::from_str("tool"), None);
        assert_eq!(Role::Assistant.as_str(), "assistant");
    }
}
