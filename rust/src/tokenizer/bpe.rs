//! BPE vocabulary, encoder, and incremental decoder.

use crate::json::Value;
use std::collections::HashMap;
use std::fmt;

#[derive(Debug)]
pub enum TokenizerError {
    Io(std::io::Error),
    Format(String),
}

impl fmt::Display for TokenizerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TokenizerError::Io(e) => write!(f, "tokenizer io error: {e}"),
            TokenizerError::Format(m) => write!(f, "tokenizer format error: {m}"),
        }
    }
}

impl std::error::Error for TokenizerError {}

/// Byte-level BPE tokenizer.
///
/// Id space (fixed by tokenizer_gen.py): `[0, byte_offset)` specials,
/// `[byte_offset, byte_offset+256)` raw bytes, then one id per merge.
pub struct Tokenizer {
    vocab_size: usize,
    byte_offset: u32,
    /// (a, b) -> merged id, rank == merged id (lower id = earlier merge).
    ranks: HashMap<(u32, u32), u32>,
    /// Token id -> byte string (empty for specials / unused ids).
    bytes: Vec<Vec<u8>>,
    specials: Vec<(String, u32)>,
}

impl Tokenizer {
    pub fn from_json(v: &Value) -> Result<Self, TokenizerError> {
        let fmt_err = |m: &str| TokenizerError::Format(m.to_string());
        let vocab_size = v
            .get("vocab_size")
            .and_then(Value::as_usize)
            .ok_or_else(|| fmt_err("missing vocab_size"))?;
        let byte_offset = v
            .get("byte_offset")
            .and_then(Value::as_u64)
            .ok_or_else(|| fmt_err("missing byte_offset"))? as u32;
        let merges = v
            .get("merges")
            .and_then(Value::as_array)
            .ok_or_else(|| fmt_err("missing merges"))?;

        let first_merge_id = byte_offset + 256;
        let mut ranks = HashMap::with_capacity(merges.len());
        let mut bytes: Vec<Vec<u8>> = Vec::with_capacity(vocab_size);
        bytes.resize(byte_offset as usize, Vec::new());
        for b in 0..=255u8 {
            bytes.push(vec![b]);
        }
        for (i, m) in merges.iter().enumerate() {
            let a = m.at(0).and_then(Value::as_u64).ok_or_else(|| fmt_err("bad merge"))? as u32;
            let b = m.at(1).and_then(Value::as_u64).ok_or_else(|| fmt_err("bad merge"))? as u32;
            let id = first_merge_id + i as u32;
            if a >= id || b >= id {
                return Err(fmt_err("merge references a later id"));
            }
            let mut buf = bytes[a as usize].clone();
            buf.extend_from_slice(&bytes[b as usize]);
            bytes.push(buf);
            ranks.insert((a, b), id);
        }
        if bytes.len() > vocab_size {
            return Err(fmt_err("more merges than vocab_size allows"));
        }
        bytes.resize(vocab_size, Vec::new()); // unused tail ids decode to ""

        let mut specials = Vec::new();
        if let Some(sp) = v.get("specials").and_then(Value::as_object) {
            for (name, id) in sp.iter() {
                let id = id.as_u64().ok_or_else(|| fmt_err("bad special id"))? as u32;
                specials.push((name.clone(), id));
            }
            // Longest-first so "<|assistant|>" wins over shorter overlaps.
            specials.sort_by_key(|(name, _)| std::cmp::Reverse(name.len()));
        }

        Ok(Self { vocab_size, byte_offset, ranks, bytes, specials })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self, TokenizerError> {
        let text = std::fs::read_to_string(path).map_err(TokenizerError::Io)?;
        let v = crate::json::parse(&text)
            .map_err(|e| TokenizerError::Format(e.to_string()))?;
        Self::from_json(&v)
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    pub fn special_id(&self, name: &str) -> Option<u32> {
        self.specials.iter().find(|(n, _)| n == name).map(|(_, id)| *id)
    }

    pub fn special_name(&self, id: u32) -> Option<&str> {
        self.specials.iter().find(|(_, i)| *i == id).map(|(n, _)| n.as_str())
    }

    /// Token id -> raw bytes ("" for specials and unused ids).
    pub fn token_bytes(&self, id: u32) -> &[u8] {
        self.bytes.get(id as usize).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Encode plain text (no special-token recognition).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids = Vec::with_capacity(text.len() / 3 + 4);
        for word in Pretokenizer::new(text) {
            self.encode_word(word, &mut ids);
        }
        ids
    }

    /// Encode text in which special-token spellings (e.g. `<|user|>`) are
    /// recognized and mapped to their reserved ids — used by the chat
    /// template renderer.
    pub fn encode_with_specials(&self, text: &str) -> Vec<u32> {
        let mut ids = Vec::new();
        let mut rest = text;
        'outer: while !rest.is_empty() {
            // Find the earliest special occurrence.
            let mut best: Option<(usize, usize, u32)> = None; // (pos, len, id)
            for (name, id) in &self.specials {
                if name.is_empty() {
                    continue;
                }
                if let Some(pos) = rest.find(name.as_str()) {
                    let better = match best {
                        None => true,
                        Some((bp, bl, _)) => pos < bp || (pos == bp && name.len() > bl),
                    };
                    if better {
                        best = Some((pos, name.len(), *id));
                    }
                }
            }
            match best {
                Some((pos, len, id)) => {
                    for word in Pretokenizer::new(&rest[..pos]) {
                        self.encode_word(word, &mut ids);
                    }
                    ids.push(id);
                    rest = &rest[pos + len..];
                    continue 'outer;
                }
                None => {
                    for word in Pretokenizer::new(rest) {
                        self.encode_word(word, &mut ids);
                    }
                    break;
                }
            }
        }
        ids
    }

    fn encode_word(&self, word: &str, out: &mut Vec<u32>) {
        let mut seq: Vec<u32> =
            word.bytes().map(|b| self.byte_offset + b as u32).collect();
        // Merge loop: repeatedly apply the lowest-rank applicable merge.
        while seq.len() >= 2 {
            let mut best: Option<(u32, usize)> = None;
            for j in 0..seq.len() - 1 {
                if let Some(&id) = self.ranks.get(&(seq[j], seq[j + 1])) {
                    if best.map_or(true, |(bid, _)| id < bid) {
                        best = Some((id, j));
                    }
                }
            }
            match best {
                Some((id, j)) => {
                    seq[j] = id;
                    seq.remove(j + 1);
                }
                None => break,
            }
        }
        out.extend_from_slice(&seq);
    }

    /// Decode ids to text, replacing invalid UTF-8 with U+FFFD.
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut buf = Vec::new();
        for &id in ids {
            buf.extend_from_slice(self.token_bytes(id));
        }
        String::from_utf8_lossy(&buf).into_owned()
    }
}

/// Incremental detokenizer for streaming: buffers bytes until they form
/// complete UTF-8 scalar values, so multi-token multibyte characters never
/// emit replacement chars mid-stream.
#[derive(Default)]
pub struct StreamDecoder {
    pending: Vec<u8>,
}

impl StreamDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one token's bytes; returns any newly-complete text.
    pub fn push(&mut self, token_bytes: &[u8]) -> String {
        self.pending.extend_from_slice(token_bytes);
        // Find the longest prefix that is valid UTF-8.
        match std::str::from_utf8(&self.pending) {
            Ok(s) => {
                let out = s.to_string();
                self.pending.clear();
                out
            }
            Err(e) => {
                let valid = e.valid_up_to();
                // If the tail can't possibly complete (error_len is Some),
                // flush it as replacement chars instead of stalling forever.
                if e.error_len().is_some() {
                    let out = String::from_utf8_lossy(&self.pending).into_owned();
                    self.pending.clear();
                    out
                } else {
                    let out =
                        unsafe { std::str::from_utf8_unchecked(&self.pending[..valid]) }
                            .to_string();
                    self.pending.drain(..valid);
                    out
                }
            }
        }
    }

    /// Flush anything buffered (end of stream).
    pub fn finish(&mut self) -> String {
        let out = String::from_utf8_lossy(&self.pending).into_owned();
        self.pending.clear();
        out
    }
}

/// GPT-2-style pretokenizer, mirroring tokenizer_gen._PRETOKEN_RE:
/// ` ?[A-Za-z]+ | ?[0-9]+ | ?[^\sA-Za-z0-9]+ | \s+`
struct Pretokenizer<'a> {
    rest: &'a str,
}

impl<'a> Pretokenizer<'a> {
    fn new(text: &'a str) -> Self {
        Self { rest: text }
    }
}

impl<'a> Iterator for Pretokenizer<'a> {
    type Item = &'a str;

    fn next(&mut self) -> Option<&'a str> {
        if self.rest.is_empty() {
            return None;
        }
        let b = self.rest.as_bytes();
        let mut i;
        // Optional single leading space joined to a following word.
        let after_space = if b[0] == b' ' { 1 } else { 0 };
        let class = b.get(after_space).map(|&c| char_class(c));
        let len = match class {
            Some(Class::Alpha) => {
                i = after_space;
                while i < b.len() && char_class(b[i]) == Class::Alpha {
                    i += 1;
                }
                i
            }
            Some(Class::Digit) => {
                i = after_space;
                while i < b.len() && char_class(b[i]) == Class::Digit {
                    i += 1;
                }
                i
            }
            Some(Class::Other) => {
                i = after_space;
                while i < b.len() && char_class(b[i]) == Class::Other {
                    i += 1;
                }
                i
            }
            // Lone space(s) at end, or whitespace run.
            _ => {
                i = 0;
                while i < b.len() && char_class(b[i]) == Class::Space {
                    i += 1;
                }
                i.max(1)
            }
        };
        // Every arm consumes at least one byte, and runs never split a
        // multibyte scalar (continuation bytes are Class::Other), so this
        // split is always on a char boundary.
        let len = len.max(1);
        let (tok, rest) = self.rest.split_at(len);
        self.rest = rest;
        Some(tok)
    }
}

#[derive(PartialEq, Clone, Copy)]
enum Class {
    Alpha,
    Digit,
    Space,
    Other,
}

fn char_class(c: u8) -> Class {
    if c.is_ascii_alphabetic() {
        Class::Alpha
    } else if c.is_ascii_digit() {
        Class::Digit
    } else if c.is_ascii_whitespace() || c == 0x0B {
        // 0x0B (vertical tab): ASCII \s in the Python reference regex.
        Class::Space
    } else {
        Class::Other
    }
}

