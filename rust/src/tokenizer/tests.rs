use super::bpe::StreamDecoder;
use super::*;
use crate::json::parse;
use crate::testutil::prop::{PropRng, Runner};

/// A small hand-built vocabulary (specials + bytes + a few merges) used
/// by unit tests that must not depend on `make artifacts`.
pub fn test_tokenizer() -> Tokenizer {
    // merges: (h,e)->264, (l,l)->265, (264="he", 265="ll")->266 ("hell"),
    // (' ', 'w')->267
    let h = 8 + b'h' as u32;
    let e = 8 + b'e' as u32;
    let l = 8 + b'l' as u32;
    let sp = 8 + b' ' as u32;
    let w = 8 + b'w' as u32;
    let json = format!(
        r#"{{
        "vocab_size": 512,
        "byte_offset": 8,
        "specials": {{"<pad>":0,"<bos>":1,"<eos>":2,"<unk>":3,
                      "<|system|>":4,"<|user|>":5,"<|assistant|>":6,"<|end|>":7}},
        "merges": [[{h},{e}],[{l},{l}],[264,265],[{sp},{w}]]
    }}"#
    );
    Tokenizer::from_json(&parse(&json).unwrap()).unwrap()
}

/// The real trained vocabulary from artifacts/, when present.
pub fn artifact_tokenizer() -> Option<Tokenizer> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tokenizer.json");
    Tokenizer::from_file(&path).ok()
}

#[test]
fn encode_applies_merges_in_rank_order() {
    let tok = test_tokenizer();
    // "hell" -> he+ll -> hell (id 266), then 'o' raw
    let ids = tok.encode("hello");
    assert_eq!(ids, vec![266, 8 + b'o' as u32]);
    // " world" -> ' w' merged (267) + o,r,l,d
    let ids = tok.encode(" world");
    assert_eq!(ids[0], 267);
    assert_eq!(ids.len(), 1 + 4);
}

#[test]
fn decode_inverts_encode() {
    let tok = test_tokenizer();
    for s in ["hello world", "hhee", "a b  c", "tab\there", "", "42,x=7!"] {
        assert_eq!(tok.decode(&tok.encode(s)), s, "{s:?}");
    }
}

#[test]
fn specials_not_produced_by_plain_encode() {
    let tok = test_tokenizer();
    let ids = tok.encode("<|user|>");
    assert!(!ids.contains(&5), "plain encode must treat tags as text");
    let ids = tok.encode_with_specials("<|user|>");
    assert_eq!(ids, vec![5]);
}

#[test]
fn encode_with_specials_mixed_content() {
    let tok = test_tokenizer();
    let ids = tok.encode_with_specials("<bos>hello<|end|>");
    assert_eq!(ids[0], 1);
    assert_eq!(*ids.last().unwrap(), 7);
    assert_eq!(tok.decode(&ids[1..ids.len() - 1]), "hello");
}

#[test]
fn unused_ids_decode_empty() {
    let tok = test_tokenizer();
    assert_eq!(tok.decode(&[400, 501]), "");
    assert_eq!(tok.token_bytes(9999), b"");
}

#[test]
fn rejects_malformed_vocab() {
    for bad in [
        r#"{"byte_offset": 8, "merges": []}"#,                       // no vocab_size
        r#"{"vocab_size": 512, "byte_offset": 8, "merges": [[999, 8]]}"#, // future id
        r#"{"vocab_size": 10, "byte_offset": 8, "merges": []}"#,     // too small
    ] {
        let v = parse(bad).unwrap();
        assert!(Tokenizer::from_json(&v).is_err(), "{bad}");
    }
}

#[test]
fn stream_decoder_handles_split_multibyte() {
    let mut d = StreamDecoder::new();
    // "é" = 0xC3 0xA9 split across two tokens
    assert_eq!(d.push(&[0xC3]), "");
    assert_eq!(d.push(&[0xA9]), "é");
    // mixed: ascii + half of a char
    assert_eq!(d.push(&[b'a', 0xE6]), "a");
    assert_eq!(d.push(&[0x97, 0xA5]), "日");
    assert_eq!(d.finish(), "");
}

#[test]
fn stream_decoder_flushes_invalid_bytes() {
    let mut d = StreamDecoder::new();
    let out = d.push(&[0xFF, b'x']);
    assert!(out.contains('\u{FFFD}'));
    assert!(out.contains('x'));
    // dangling prefix flushed lossily at finish
    assert_eq!(d.push(&[0xC3]), "");
    assert_eq!(d.finish(), "\u{FFFD}");
}

#[test]
fn prop_roundtrip_ascii_and_unicode() {
    let Some(tok) = artifact_tokenizer() else { return };
    Runner::new("tokenizer_roundtrip", 200).run(|rng: &mut PropRng| {
        let s = rng.string(80);
        let ids = tok.encode(&s);
        let back = tok.decode(&ids);
        if back != s {
            return Err(format!("{s:?} -> {ids:?} -> {back:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_stream_decode_equals_batch_decode() {
    let Some(tok) = artifact_tokenizer() else { return };
    Runner::new("stream_decode", 200).run(|rng: &mut PropRng| {
        let s = rng.string(60);
        let ids = tok.encode(&s);
        let mut d = StreamDecoder::new();
        let mut streamed = String::new();
        for &id in &ids {
            streamed.push_str(&d.push(tok.token_bytes(id)));
        }
        streamed.push_str(&d.finish());
        if streamed != s {
            return Err(format!("stream {streamed:?} != {s:?}"));
        }
        Ok(())
    });
}

#[test]
fn artifact_vocab_compresses_english() {
    let Some(tok) = artifact_tokenizer() else { return };
    let text = "The engine streams tokens back to the application.";
    let ids = tok.encode(text);
    assert!(ids.len() * 2 < text.len(), "got {} ids", ids.len());
    assert_eq!(tok.decode(&ids), text);
}

#[test]
fn fixtures_match_python() {
    // Pin the Rust encoder to the Python reference byte-for-byte: the
    // fixtures are produced at artifact-build time by compile/aot.py.
    let Some(tok) = artifact_tokenizer() else { return };
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tokenizer_fixtures.json");
    let Ok(text) = std::fs::read_to_string(&path) else { return };
    let v = parse(&text).unwrap();
    let cases = v.as_array().unwrap();
    assert!(cases.len() >= 8);
    for case in cases {
        let s = case.get("text").unwrap().as_str().unwrap();
        let want: Vec<u32> = case
            .get("ids")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|x| x.as_u64().unwrap() as u32)
            .collect();
        assert_eq!(tok.encode(s), want, "text {s:?}");
        assert_eq!(tok.decode(&want), s, "decode {s:?}");
    }
}
