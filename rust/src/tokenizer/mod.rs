//! Byte-level BPE tokenizer.
//!
//! WebLLM runs HuggingFace tokenizers compiled to WASM on the browser's
//! CPU; this is the native-Rust equivalent, loading the vocabulary that
//! `python/compile/tokenizer_gen.py` trains at build time
//! (`artifacts/tokenizer.json`). Encoding mirrors the Python reference
//! exactly (same pretokenizer, same merge-rank loop) — pytest and cargo
//! test both pin the mapping.

mod bpe;
mod template;

pub use bpe::{StreamDecoder, Tokenizer, TokenizerError};
pub use template::{render_chat, ChatMessage, Role};

#[cfg(test)]
pub mod tests;
