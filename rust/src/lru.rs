//! Shared clock-stamped LRU map.
//!
//! Three subsystems keep small bounded caches with identical eviction
//! semantics: the grammar [`MaskCache`](crate::grammar::MaskCache)
//! (fingerprint → token bitmask), the engine's compiled-grammar table
//! (grammar key → compiled grammar + caches), and the fast-forward
//! run cache (state fingerprint → forced token run). This module holds
//! the one implementation they share.
//!
//! Recency is a strictly increasing logical clock, bumped on every
//! touch ([`get`](LruMap::get) and [`insert`](LruMap::insert)). The
//! victim is the entry with the smallest `(stamp, key)` pair — the
//! key tiebreak makes eviction deterministic even for entries stamped
//! by a bulk seed pass, which matters for reproducible engine stats.
//!
//! Eviction is O(n) scan on insert-at-capacity. Every user holds at
//! most a few hundred entries, so a linked-list LRU would buy nothing
//! but unsafe code or index juggling.

use std::collections::HashMap;
use std::hash::Hash;

struct Entry<V> {
    value: V,
    stamp: u64,
}

/// A bounded map evicting the least-recently-used entry on overflow.
///
/// ```
/// use webllm::lru::LruMap;
/// let mut m: LruMap<u32, &str> = LruMap::new(2);
/// m.insert(1, "a");
/// m.insert(2, "b");
/// m.get(&1); // bump 1; 2 is now LRU
/// let evicted = m.insert(3, "c");
/// assert_eq!(evicted, Some((2, "b")));
/// assert_eq!(m.len(), 2);
/// ```
pub struct LruMap<K, V> {
    entries: HashMap<K, Entry<V>>,
    capacity: usize,
    clock: u64,
    evictions: u64,
}

impl<K: Eq + Hash + Ord + Clone, V> LruMap<K, V> {
    /// A map holding at most `capacity` entries (clamped to ≥ 1).
    pub fn new(capacity: usize) -> Self {
        LruMap {
            entries: HashMap::new(),
            capacity: capacity.max(1),
            clock: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Evictions performed since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Look up `key`, marking it most-recently-used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        self.clock += 1;
        let clock = self.clock;
        self.entries.get_mut(key).map(|e| {
            e.stamp = clock;
            &e.value
        })
    }

    /// Look up `key` without touching recency.
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.entries.get(key).map(|e| &e.value)
    }

    /// Insert `key → value` as most-recently-used, evicting the LRU
    /// entry first if the map is full and `key` is new. Returns the
    /// evicted pair so callers can fold its counters into their stats.
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            e.value = value;
            e.stamp = self.clock;
            return None;
        }
        let mut evicted = None;
        if self.entries.len() >= self.capacity {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(k, e)| (e.stamp, (*k).clone()))
                .map(|(k, _)| k.clone());
            if let Some(k) = victim {
                let e = self.entries.remove(&k).unwrap();
                self.evictions += 1;
                evicted = Some((k, e.value));
            }
        }
        self.entries.insert(
            key,
            Entry {
                value,
                stamp: self.clock,
            },
        );
        evicted
    }

    /// Iterate over values in arbitrary order (no recency change).
    pub fn values(&self) -> impl Iterator<Item = &V> {
        self.entries.values().map(|e| &e.value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used() {
        let mut m = LruMap::new(2);
        assert!(m.is_empty());
        m.insert(1u32, 10);
        m.insert(2, 20);
        assert_eq!(m.get(&1), Some(&10)); // 2 becomes LRU
        assert_eq!(m.insert(3, 30), Some((2, 20)));
        assert_eq!(m.len(), 2);
        assert_eq!(m.evictions(), 1);
        assert_eq!(m.peek(&2), None);
        assert_eq!(m.peek(&1), Some(&10));
        assert_eq!(m.peek(&3), Some(&30));
    }

    #[test]
    fn reinsert_updates_without_eviction() {
        let mut m = LruMap::new(1);
        m.insert(7u64, "a");
        assert_eq!(m.insert(7, "b"), None);
        assert_eq!(m.evictions(), 0);
        assert_eq!(m.get(&7), Some(&"b"));
    }

    #[test]
    fn eviction_order_is_insertion_order_when_untouched() {
        let mut m = LruMap::new(3);
        m.insert(5u32, ());
        m.insert(1, ());
        m.insert(9, ());
        // 5 is oldest: it goes first.
        assert_eq!(m.insert(2, ()), Some((5, ())));
        assert_eq!(m.insert(3, ()), Some((1, ())));
    }

    #[test]
    fn capacity_clamps_to_one() {
        let mut m = LruMap::new(0);
        assert_eq!(m.capacity(), 1);
        m.insert(1u8, 1);
        assert_eq!(m.insert(2, 2), Some((1, 1)));
    }

    #[test]
    fn values_sees_everything() {
        let mut m = LruMap::new(4);
        for i in 0..4u32 {
            m.insert(i, i * 2);
        }
        let mut vs: Vec<u32> = m.values().copied().collect();
        vs.sort_unstable();
        assert_eq!(vs, vec![0, 2, 4, 6]);
    }
}
