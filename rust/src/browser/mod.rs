//! Browser-environment cost model.
//!
//! The physical testbed has no browser, WebGPU, or WASM sandbox, so the
//! *costs* the paper's in-browser deployment pays relative to native
//! MLC-LLM are modeled explicitly (DESIGN.md §5, substitution 1):
//!
//! 1. **Worker message boundary** — real: requests/responses cross a
//!    channel as serialized JSON (`coordinator::messages`). Nothing to
//!    model; the serialization and thread hop actually happen.
//! 2. **WebGPU execution overhead** — two real mechanisms:
//!    (a) per-dispatch cost: every kernel launch goes through the WebGPU
//!    command encoder + Dawn/wgpu validation before reaching Metal
//!    (`dispatch_overhead_us` x the per-step dispatch count estimated
//!    from the model structure, `runtime::exec::dispatch_estimate`);
//!    (b) a bandwidth tax: WebGPU mandates bounds-checked ("robust")
//!    storage-buffer access, taxing every byte of weight traffic —
//!    decode is weight-bandwidth-bound, so this is the dominant term
//!    (`bandwidth_tax_us_per_mb` x weight MB touched per step). The tax
//!    is what makes the *bigger* (more bandwidth-bound) model retain
//!    less in browser mode, reproducing Table 1's ordering from a real
//!    mechanism rather than a fitted curve; the magnitude is calibrated
//!    to the scaled testbed in EXPERIMENTS.md §Calibration.
//! 3. **WASM CPU slowdown** — CPU-side subsystems (tokenizer, grammar,
//!    detokenizer) run ~1.5-2.5x slower compiled to WASM (Haas et al.
//!    2017, Jangda et al. 2019). Modeled as a busy-wait proportional to
//!    the *measured* duration of each CPU stage (`charge_cpu`).
//!
//! Native mode = no `BrowserEnv` at all; Table 1's "Perf. Retained" is
//! browser-mode tok/s over native tok/s.

use std::cell::Cell;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BrowserConfig {
    /// Per-dispatch WebGPU submit/validation overhead, microseconds.
    /// Default calibrated in EXPERIMENTS.md §Calibration.
    pub dispatch_overhead_us: f64,
    /// Bounds-checked ("robust access") storage-buffer tax on weight
    /// traffic, microseconds per MiB touched per step.
    pub bandwidth_tax_us_per_mb: f64,
    /// WASM slowdown multiplier applied to CPU-stage durations (the model
    /// charges (factor - 1) x measured native duration).
    pub wasm_slowdown: f64,
}

impl Default for BrowserConfig {
    fn default() -> Self {
        Self {
            // See EXPERIMENTS.md §Calibration for how these were picked.
            dispatch_overhead_us: 8.0,
            bandwidth_tax_us_per_mb: 1000.0,
            wasm_slowdown: 1.8,
        }
    }
}

/// Browser-mode overhead injector. Cloneable handle; accounting is
/// per-instance (one per engine).
pub struct BrowserEnv {
    cfg: BrowserConfig,
    injected_us: Cell<f64>,
    dispatches: Cell<u64>,
}

impl BrowserEnv {
    pub fn new(cfg: BrowserConfig) -> Self {
        Self { cfg, injected_us: Cell::new(0.0), dispatches: Cell::new(0) }
    }

    pub fn config(&self) -> &BrowserConfig {
        &self.cfg
    }

    /// Charge one engine step's kernel dispatches plus the robust-access
    /// bandwidth tax on the step's weight traffic.
    pub fn charge_dispatches(&self, base_dispatches: usize, weight_bytes: usize) {
        self.dispatches.set(self.dispatches.get() + base_dispatches as u64);
        let mb = weight_bytes as f64 / (1 << 20) as f64;
        self.busy_wait_us(
            base_dispatches as f64 * self.cfg.dispatch_overhead_us
                + mb * self.cfg.bandwidth_tax_us_per_mb,
        );
    }

    /// Charge a CPU-side stage (tokenize/grammar/detokenize) that took
    /// `native` wall time: inject the extra time WASM would have cost.
    pub fn charge_cpu(&self, native: Duration) {
        let extra_us = native.as_secs_f64() * 1e6 * (self.cfg.wasm_slowdown - 1.0);
        self.busy_wait_us(extra_us);
    }

    /// Run `f`, then charge its WASM slowdown. Returns f's output.
    pub fn cpu_stage<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.charge_cpu(t0.elapsed());
        out
    }

    /// Total overhead injected so far (microseconds) — reported by the
    /// benches to show where browser-mode time goes.
    pub fn injected_us(&self) -> f64 {
        self.injected_us.get()
    }

    pub fn dispatch_count(&self) -> u64 {
        self.dispatches.get()
    }

    fn busy_wait_us(&self, us: f64) {
        self.injected_us.set(self.injected_us.get() + us);
        let until = Instant::now() + Duration::from_nanos((us * 1e3) as u64);
        // Busy-wait rather than sleep: models synchronous validation work
        // on the submitting thread (and keeps sub-ms precision).
        while Instant::now() < until {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_charging_accumulates() {
        let env = BrowserEnv::new(BrowserConfig {
            dispatch_overhead_us: 10.0,
            bandwidth_tax_us_per_mb: 5.0,
            wasm_slowdown: 2.0,
        });
        let t0 = Instant::now();
        env.charge_dispatches(10, 3 << 20); // 100us dispatch + 15us tax
        let elapsed = t0.elapsed();
        assert_eq!(env.dispatch_count(), 10);
        assert!((env.injected_us() - 115.0).abs() < 1e-9);
        assert!(elapsed >= Duration::from_micros(110), "{elapsed:?}");
    }

    #[test]
    fn cpu_stage_charges_slowdown() {
        let env = BrowserEnv::new(BrowserConfig {
            dispatch_overhead_us: 0.0,
            bandwidth_tax_us_per_mb: 0.0,
            wasm_slowdown: 3.0,
        });
        let t0 = Instant::now();
        let out = env.cpu_stage(|| {
            let until = Instant::now() + Duration::from_millis(2);
            while Instant::now() < until {}
            42
        });
        assert_eq!(out, 42);
        // 2ms native + ~4ms injected
        assert!(t0.elapsed() >= Duration::from_micros(5500), "{:?}", t0.elapsed());
        assert!(env.injected_us() >= 3900.0);
    }

    #[test]
    fn bigger_weights_pay_more_tax() {
        let env = BrowserEnv::new(BrowserConfig::default());
        env.charge_dispatches(100, 40 << 20);
        let big = env.injected_us();
        let env2 = BrowserEnv::new(BrowserConfig::default());
        env2.charge_dispatches(100, 18 << 20);
        assert!(big > env2.injected_us());
    }
}
