//! PJRT runtime: load AOT artifacts, keep weights + KV pools device-
//! resident, execute prefill/decode from the L3 hot path.
//!
//! This is the Rust analog of WebLLM's WebGPU runtime glue (TVMjs): the
//! browser fetches compiled kernels + weights once, uploads them to GPU
//! buffers, and every request just launches kernels. Here: HLO text is
//! compiled once per (model, phase, static shape) at load; weights are
//! uploaded once as `PjRtBuffer`s; each step passes small host inputs
//! (token ids, block tables) and chains the returned cache buffers into
//! the next call (the vendored `xla` crate is patched to untuple results
//! so caches never round-trip through host literals — see DESIGN.md §6).
//!
//! Threading: the `xla` crate's handles are `Rc`-based (`!Send`), so a
//! client and every runtime it owns live on ONE thread — naturally the
//! worker thread (`coordinator::worker`), exactly where WebLLM's
//! `MLCEngine` keeps its GPUDevice.
//!
//! The engine itself is written against the [`ModelBackend`] trait, not
//! this XLA runtime: [`reference::ReferenceBackend`] implements the same
//! contract in pure Rust (seeded-deterministic logits over real paged-KV
//! semantics) so the full pipeline runs — and is tested — without
//! artifacts.

mod backend;
mod exec;
pub mod fault;
mod literal;
pub mod reference;

pub use backend::ModelBackend;
pub use exec::{FaultClass, ModelRuntime, RuntimeError, StepOutput};
pub use fault::{FaultCounters, FaultInjectingBackend, FaultKind, FaultPlan};
pub use reference::ReferenceBackend;

use std::cell::RefCell;

thread_local! {
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// The calling thread's PJRT CPU client (created on first use; one per
/// thread because the handle is not `Send`).
pub fn thread_client() -> Result<xla::PjRtClient, xla::Error> {
    CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if slot.is_none() {
            *slot = Some(xla::PjRtClient::cpu()?);
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}
