//! Deterministic fault injection over any [`ModelBackend`].
//!
//! The offline analog of WebGPU device unreliability: browsers revoke
//! GPU devices on tab backgrounding, driver resets, and memory pressure
//! (`device.lost` resolves and every subsequent submit fails), drivers
//! hiccup transiently, and buggy kernels return NaN rows. The engine's
//! recovery paths (`coordinator::engine`) must be *exactly* testable, so
//! [`FaultInjectingBackend`] wraps a real backend and injects faults on
//! a reproducible schedule keyed by a monotonic operation index — the
//! same schedule always produces the same faults at the same ops, which
//! lets tests assert recovery counters match the plan exactly.
//!
//! The op index advances on every `prefill_chunk` / `verify_chunk` /
//! `decode` call, *including* calls that fail — so a retry of a failed
//! op observes the next schedule entry, and back-to-back scheduled
//! transients model a fault that outlives the retry budget.

use std::time::Duration;

use super::backend::ModelBackend;
use super::exec::{RuntimeError, StepOutput};
use crate::models::ModelConfig;

/// What to inject at a scheduled operation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// One-shot retryable failure ([`RuntimeError::Transient`]); the op
    /// does not execute. The next attempt (next op index) sees whatever
    /// the schedule says there.
    Transient,
    /// Fatal device loss ([`RuntimeError::DeviceLost`]): the op does not
    /// execute and **every** subsequent op fails the same way until
    /// [`ModelBackend::reset_cache`] — the sticky semantics of a lost
    /// WebGPU device.
    DeviceLost,
    /// Data-plane corruption: the op executes normally, then one live
    /// logits row is overwritten with NaN. The payload selects which row
    /// (mod the number of live rows for decode; prefill/verify poison
    /// the row the engine is guaranteed to consume).
    NanRow(usize),
    /// Latency fault: sleep this many milliseconds, then execute the op
    /// normally. Exercises the engine's stuck-step watchdog.
    StallMs(u64),
}

/// A reproducible schedule: `(op_index, fault)` pairs over the wrapped
/// backend's monotonic operation counter.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    schedule: Vec<(u64, FaultKind)>,
}

impl FaultPlan {
    /// An explicit schedule. Later entries win on duplicate op indices.
    pub fn at(schedule: Vec<(u64, FaultKind)>) -> Self {
        Self { schedule }
    }

    /// A seeded pseudo-random schedule over ops `[0, horizon)`: each op
    /// faults with probability `rate_pct`%, drawing uniformly from
    /// transient / NaN-row / short-stall. Device loss is deliberately
    /// excluded (it is sticky, so a random mix would wedge a bare
    /// backend); add one explicitly with [`Self::then`].
    pub fn seeded(seed: u64, horizon: u64, rate_pct: u64) -> Self {
        let mut s = seed | 1;
        let mut roll = move || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            s >> 33
        };
        let schedule = (0..horizon)
            .filter_map(|op| {
                if roll() % 100 >= rate_pct {
                    return None;
                }
                let kind = match roll() % 3 {
                    0 => FaultKind::Transient,
                    1 => FaultKind::NanRow(roll() as usize % 8),
                    _ => FaultKind::StallMs(1 + roll() % 3),
                };
                Some((op, kind))
            })
            .collect();
        Self { schedule }
    }

    /// Append one more scheduled fault (builder-style).
    pub fn then(mut self, op: u64, kind: FaultKind) -> Self {
        self.schedule.push((op, kind));
        self
    }

    /// Scheduled fault for `op`, if any (last entry wins).
    fn lookup(&self, op: u64) -> Option<FaultKind> {
        self.schedule.iter().rev().find(|(o, _)| *o == op).map(|(_, k)| *k)
    }

    /// Number of scheduled faults.
    pub fn len(&self) -> usize {
        self.schedule.len()
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.schedule.is_empty()
    }
}

/// Injection tallies, for asserting a run observed its schedule exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Total scheduled faults that actually fired (sticky device-lost
    /// repeats are not re-counted).
    pub injected: u64,
    pub transient: u64,
    pub device_lost: u64,
    pub nan_rows: u64,
    pub stalls: u64,
}

/// [`ModelBackend`] decorator that injects the faults a [`FaultPlan`]
/// schedules, delegating everything else to the wrapped backend.
///
/// Composes with [`super::ReferenceBackend`] (the intended pairing: a
/// deterministic model under a deterministic fault schedule) and equally
/// with the compiled runtime.
pub struct FaultInjectingBackend {
    inner: Box<dyn ModelBackend>,
    plan: FaultPlan,
    /// Monotonic operation index; advances on every prefill/verify/
    /// decode call, successful or not.
    op: u64,
    /// Sticky device-lost latch; cleared only by `reset_cache`.
    lost: bool,
    counters: FaultCounters,
}

impl FaultInjectingBackend {
    pub fn new(inner: Box<dyn ModelBackend>, plan: FaultPlan) -> Self {
        Self { inner, plan, op: 0, lost: false, counters: FaultCounters::default() }
    }

    /// Operations attempted so far (the next op's schedule index).
    pub fn op(&self) -> u64 {
        self.op
    }

    /// True while the simulated device is lost.
    pub fn is_lost(&self) -> bool {
        self.lost
    }

    pub fn counters(&self) -> FaultCounters {
        self.counters
    }

    /// Consume the schedule entry for the current op. `Err` means the op
    /// must not execute; `Ok(Some(kind))` carries a data-plane/latency
    /// fault for the caller to apply around the real op.
    fn pre_op(&mut self) -> Result<Option<FaultKind>, RuntimeError> {
        let idx = self.op;
        self.op += 1;
        if self.lost {
            return Err(RuntimeError::DeviceLost("device already lost (awaiting reset)".into()));
        }
        match self.plan.lookup(idx) {
            None => Ok(None),
            Some(FaultKind::Transient) => {
                self.counters.injected += 1;
                self.counters.transient += 1;
                Err(RuntimeError::Transient(format!("injected transient at op {idx}")))
            }
            Some(FaultKind::DeviceLost) => {
                self.lost = true;
                self.counters.injected += 1;
                self.counters.device_lost += 1;
                Err(RuntimeError::DeviceLost(format!("injected device loss at op {idx}")))
            }
            Some(kind @ (FaultKind::NanRow(_) | FaultKind::StallMs(_))) => Ok(Some(kind)),
        }
    }

    fn stall(&mut self, kind: Option<FaultKind>) {
        if let Some(FaultKind::StallMs(ms)) = kind {
            self.counters.injected += 1;
            self.counters.stalls += 1;
            std::thread::sleep(Duration::from_millis(ms));
        }
    }

    /// Overwrite logits row `row` (of `rows` total) with NaN.
    fn poison(&mut self, out: &mut StepOutput, row: usize, rows: usize) {
        debug_assert!(row < rows);
        let vocab = self.inner.config().vocab_size;
        debug_assert!(out.logits.len() >= rows * vocab);
        out.logits[row * vocab..(row + 1) * vocab].fill(f32::NAN);
        self.counters.injected += 1;
        self.counters.nan_rows += 1;
    }
}

impl ModelBackend for FaultInjectingBackend {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn compiled_chunks(&self) -> Vec<usize> {
        self.inner.compiled_chunks()
    }

    fn compiled_batches(&self) -> Vec<usize> {
        self.inner.compiled_batches()
    }

    /// Clears the device-lost latch (the offline analog of requesting a
    /// fresh GPUDevice) and resets the wrapped backend's KV pools. The
    /// op counter and schedule keep advancing — recovery itself can be
    /// scheduled to fault.
    fn reset_cache(&mut self) -> Result<(), RuntimeError> {
        self.lost = false;
        self.inner.reset_cache()
    }

    fn prefill_chunk(
        &mut self,
        ids: &[i32],
        start_pos: usize,
        n: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        let fault = self.pre_op()?;
        self.stall(fault);
        let mut out = self.inner.prefill_chunk(ids, start_pos, n, block_table)?;
        if let Some(FaultKind::NanRow(_)) = fault {
            // Prefill returns exactly one row; it is always consumed (the
            // engine scans every chunk's returned logits).
            self.poison(&mut out, 0, 1);
        }
        Ok(out)
    }

    fn verify_chunk(
        &mut self,
        ids: &[i32],
        start_pos: usize,
        n: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        let fault = self.pre_op()?;
        self.stall(fault);
        let mut out = self.inner.verify_chunk(ids, start_pos, n, block_table)?;
        if let Some(FaultKind::NanRow(_)) = fault {
            // Row 0 scores the sequence's own last sampled token, so the
            // engine consumes it unconditionally regardless of how many
            // speculative tokens it accepts.
            self.poison(&mut out, 0, n);
        }
        Ok(out)
    }

    fn decode(
        &mut self,
        ids: &[i32],
        positions: &[i32],
        seq_lens: &[i32],
        block_tables: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        let fault = self.pre_op()?;
        self.stall(fault);
        let mut out = self.inner.decode(ids, positions, seq_lens, block_tables)?;
        if let Some(FaultKind::NanRow(r)) = fault {
            // Target a live row (seq_len > 0) so the corruption is
            // observed; padding rows are never consumed, and poisoning
            // one would make the schedule under-count.
            let live: Vec<usize> =
                (0..seq_lens.len()).filter(|&i| seq_lens[i] > 0).collect();
            if !live.is_empty() {
                self.poison(&mut out, live[r % live.len()], seq_lens.len());
            }
        }
        Ok(out)
    }

    /// Plain delegation — page copies are pool maintenance, not a model
    /// op; the fault schedule's op counter only advances on compute.
    fn supports_page_copy(&self) -> bool {
        self.inner.supports_page_copy()
    }

    fn copy_page(&mut self, src: u32, dst: u32) -> Result<(), RuntimeError> {
        self.inner.copy_page(src, dst)
    }

    fn weight_bytes(&self) -> usize {
        self.inner.weight_bytes()
    }

    fn load_seconds(&self) -> f64 {
        self.inner.load_seconds()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::reference_model_config;
    use crate::runtime::reference::ReferenceBackend;
    use crate::runtime::FaultClass;

    fn reference() -> Box<dyn ModelBackend> {
        Box::new(ReferenceBackend::new(
            reference_model_config("tiny-ref").unwrap(),
            7,
            Some(2),
            None,
        ))
    }

    fn wrapped(plan: FaultPlan) -> FaultInjectingBackend {
        FaultInjectingBackend::new(reference(), plan)
    }

    fn padded(ids: &[i32], chunk: usize) -> Vec<i32> {
        let mut v = ids.to_vec();
        v.resize(chunk, 0);
        v
    }

    /// Block table with one real page, padded with garbage page 0.
    fn table(rt: &dyn ModelBackend, page: i32) -> Vec<i32> {
        let mut bt = vec![0i32; rt.config().max_pages_per_seq()];
        bt[0] = page;
        bt
    }

    #[test]
    fn transient_fails_once_then_passes_through_identically() {
        let mut clean = reference();
        let bt = table(clean.as_ref(), 1);
        let want = clean.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap();

        let mut rt = wrapped(FaultPlan::at(vec![(0, FaultKind::Transient)]));
        let err = rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap_err();
        assert_eq!(err.class(), FaultClass::Transient);
        // Retry (op 1, unscheduled) executes and matches the clean run.
        let got = rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap();
        assert_eq!(got.logits, want.logits);
        assert_eq!(
            rt.counters(),
            FaultCounters { injected: 1, transient: 1, ..Default::default() }
        );
    }

    #[test]
    fn device_loss_is_sticky_until_reset() {
        let mut rt = wrapped(FaultPlan::at(vec![(1, FaultKind::DeviceLost)]));
        let bt = table(&rt, 1);
        rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap(); // op 0
        let err = rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap_err(); // op 1
        assert_eq!(err.class(), FaultClass::DeviceLost);
        assert!(rt.is_lost());
        // Every op after the loss fails the same way, schedule or not...
        for _ in 0..3 {
            let err = rt.decode(&[9], &[2], &[3], &bt).unwrap_err();
            assert_eq!(err.class(), FaultClass::DeviceLost);
        }
        // ...and only the loss itself was counted.
        assert_eq!(rt.counters().injected, 1);
        assert_eq!(rt.counters().device_lost, 1);
        // reset_cache restores the device (and wipes KV, so re-prefill).
        rt.reset_cache().unwrap();
        assert!(!rt.is_lost());
        rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap();
    }

    #[test]
    fn nan_row_poisons_exactly_the_targeted_live_decode_row() {
        let mut rt = wrapped(FaultPlan::at(vec![(1, FaultKind::NanRow(0))]));
        let vocab = rt.config().vocab_size;
        let mp = rt.config().max_pages_per_seq();
        let bt = table(&rt, 1);
        rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap(); // op 0
        // Batch of 2: row 0 live, row 1 padding (seq_len 0).
        let mut bt2 = vec![0i32; 2 * mp];
        bt2[..mp].copy_from_slice(&bt);
        let out = rt.decode(&[9, 0], &[2, 0], &[3, 0], &bt2).unwrap(); // op 1
        assert!(out.logits[..vocab].iter().all(|x| x.is_nan()), "live row not poisoned");
        assert!(out.logits[vocab..].iter().all(|x| x.is_finite()), "padding row poisoned");
        assert_eq!(rt.counters().nan_rows, 1);
    }

    #[test]
    fn nan_row_index_wraps_over_live_rows_only() {
        // NanRow(5) over a single live row must land on that row, not a
        // padding slot: injection targets what the engine consumes.
        let mut rt = wrapped(FaultPlan::at(vec![(1, FaultKind::NanRow(5))]));
        let vocab = rt.config().vocab_size;
        let bt = table(&rt, 1);
        rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap();
        let out = rt.decode(&[9], &[2], &[3], &bt).unwrap();
        assert!(out.logits[..vocab].iter().all(|x| x.is_nan()));
    }

    #[test]
    fn verify_chunk_poisons_row_zero() {
        let mut rt = wrapped(FaultPlan::at(vec![(1, FaultKind::NanRow(3))]));
        let vocab = rt.config().vocab_size;
        let bt = table(&rt, 1);
        rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap(); // op 0
        let out = rt.verify_chunk(&padded(&[9, 9, 9], 16), 2, 3, &bt).unwrap(); // op 1
        assert!(out.logits[..vocab].iter().all(|x| x.is_nan()), "row 0 not poisoned");
        assert!(out.logits[vocab..].iter().all(|x| x.is_finite()), "later rows poisoned");
        // The wrapper's verify is ONE op even though the reference
        // default decomposes into n decodes internally.
        assert_eq!(rt.op(), 2);
    }

    #[test]
    fn stall_executes_after_sleeping() {
        let mut rt = wrapped(FaultPlan::at(vec![(0, FaultKind::StallMs(5))]));
        let bt = table(&rt, 1);
        let t0 = std::time::Instant::now();
        let out = rt.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(5));
        assert!(out.logits.iter().all(|x| x.is_finite()));
        assert_eq!(rt.counters().stalls, 1);
    }

    #[test]
    fn seeded_plan_is_reproducible_and_loss_free() {
        let a = FaultPlan::seeded(0xFA17, 200, 10);
        let b = FaultPlan::seeded(0xFA17, 200, 10);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert!(!a.is_empty(), "10% over 200 ops scheduled nothing");
        assert!(a.len() < 60, "rate wildly off");
        for op in 0..200 {
            assert_ne!(a.lookup(op), Some(FaultKind::DeviceLost));
        }
        // Distinct seeds disagree somewhere.
        let c = FaultPlan::seeded(0xFA18, 200, 10);
        assert_ne!(format!("{a:?}"), format!("{c:?}"));
    }

    #[test]
    fn unscheduled_ops_are_byte_transparent() {
        // Same sequence of ops with an empty plan must match the bare
        // backend exactly — the decorator must add nothing but faults.
        let mut clean = reference();
        let mut rt = wrapped(FaultPlan::default());
        let bt = table(&rt, 1);
        let a = clean.prefill(&padded(&[1, 2, 3], 16), 3, &bt).unwrap();
        let b = rt.prefill(&padded(&[1, 2, 3], 16), 3, &bt).unwrap();
        assert_eq!(a.logits, b.logits);
        let a = clean.decode(&[7], &[3], &[4], &bt).unwrap();
        let b = rt.decode(&[7], &[3], &[4], &bt).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(rt.counters(), FaultCounters::default());
    }
}
