//! The engine-facing execution contract.
//!
//! `MLCEngine` drives models exclusively through [`ModelBackend`]; it
//! never names a concrete runtime. Two implementations exist:
//!
//! * [`super::ModelRuntime`] — compiled AOT artifacts executed through
//!   the PJRT client (requires `make artifacts`); the production path.
//! * [`super::ReferenceBackend`] — a pure-Rust, dependency-free,
//!   seeded-deterministic model of the same contract; what CI runs the
//!   whole pipeline against when no artifacts exist.
//!
//! The contract is the paper's runtime boundary (WebLLM's TVMjs glue):
//! static-shape prefill/decode executables selected from a compiled
//! menu, paged KV state owned by the backend and addressed by block
//! tables, logits returned to the host per step.

use super::exec::{RuntimeError, StepOutput};
use crate::models::ModelConfig;

/// One loaded model as the engine sees it: a static-shape prefill/decode
/// menu over backend-resident paged KV state.
///
/// Implementations must honor the KV contract: logits are a function of
/// the *full token prefix* a sequence's block table addresses, so
/// chunked prefill, batched decode rows, padding slots, and prefix-page
/// reuse are all observable through the returned logits.
pub trait ModelBackend {
    /// Architecture + scheduling config (shape menus, page geometry).
    fn config(&self) -> &ModelConfig;

    /// Prefill chunk sizes this backend can execute, ascending.
    fn compiled_chunks(&self) -> Vec<usize>;

    /// Decode batch sizes this backend can execute, ascending.
    fn compiled_batches(&self) -> Vec<usize>;

    /// Reset the KV pools to their pristine state (bench/test isolation).
    fn reset_cache(&mut self) -> Result<(), RuntimeError>;

    /// Run one *positioned* prefill chunk for a single sequence.
    ///
    /// `ids` must already be padded to a compiled chunk size; the chunk's
    /// `n` valid tokens occupy absolute positions
    /// `start_pos..start_pos + n` of the sequence, addressed through
    /// `block_table` (the sequence's pages padded with the garbage page 0
    /// to `max_pages_per_seq`). The backend writes those positions' KV
    /// and attends over the **full prefix** `[0, start_pos + n)` — every
    /// position below `start_pos` must already be resident in the pages
    /// the table names (written by an earlier chunk, or reused verbatim
    /// from a prefix-cache hit). Returns the logits of the chunk's last
    /// valid token, `[vocab]`.
    ///
    /// This is what lets the scheduler slice a long prompt into
    /// budget-sized chunks interleaved with decode steps, and skip
    /// fully-cached leading pages entirely (start at the prefix-cache
    /// boundary).
    fn prefill_chunk(
        &mut self,
        ids: &[i32],
        start_pos: usize,
        n: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError>;

    /// Whole-prompt prefill from position 0 — equivalent to (and provided
    /// as) `prefill_chunk(ids, 0, seq_len, block_table)`. Kept as the
    /// entry point for benches and direct runtime tests; the engine
    /// always calls [`Self::prefill_chunk`].
    fn prefill(
        &mut self,
        ids: &[i32],
        seq_len: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        self.prefill_chunk(ids, 0, seq_len, block_table)
    }

    /// Verify a speculative run: score `n` consecutive tokens of one
    /// sequence in a single positioned call, returning the logits *after*
    /// each of them — `[n, vocab]` row-major, where row `i` is the
    /// distribution conditioned on the prefix `[0, start_pos + i + 1)`.
    ///
    /// `ids`, `start_pos` and `block_table` follow the
    /// [`Self::prefill_chunk`] contract exactly (padded chunk, absolute
    /// positions, resident prefix below `start_pos`); the only
    /// difference is that every valid position's logits come back, not
    /// just the last one's. The KV for positions
    /// `start_pos..start_pos + n` is written as a side effect, so after
    /// a partial accept the caller must treat the rejected suffix as
    /// garbage (track it via `Sequence::written`) and overwrite it.
    ///
    /// The default implementation runs `n` single-row decode steps, so
    /// every backend supports verification; backends with a batched
    /// scoring path (one forward pass for the whole run) override it.
    fn verify_chunk(
        &mut self,
        ids: &[i32],
        start_pos: usize,
        n: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        let vocab = self.config().vocab_size;
        let batch = self.config().pick_batch(1).ok_or_else(|| {
            RuntimeError::Shape("no compiled decode batch can verify a single row".into())
        })?;
        let mp = self.config().max_pages_per_seq();
        let mut out = StepOutput {
            logits: Vec::with_capacity(n * vocab),
            dispatches: 0,
            exec_seconds: 0.0,
        };
        for i in 0..n {
            let mut row_ids = vec![0i32; batch];
            let mut positions = vec![0i32; batch];
            let mut seq_lens = vec![0i32; batch];
            let mut tables = vec![0i32; batch * mp];
            row_ids[0] = ids[i];
            positions[0] = (start_pos + i) as i32;
            seq_lens[0] = (start_pos + i + 1) as i32;
            tables[..mp].copy_from_slice(&block_table[..mp]);
            let step = self.decode(&row_ids, &positions, &seq_lens, &tables)?;
            out.logits.extend_from_slice(&step.logits[..vocab]);
            out.dispatches += step.dispatches;
            out.exec_seconds += step.exec_seconds;
        }
        Ok(out)
    }

    /// Run one batched decode step.
    ///
    /// All slices are `batch`-sized (a compiled batch size); padding
    /// slots use seq_len 0 / position 0 / a garbage-page block-table
    /// row. Returns logits `[batch * vocab]`.
    fn decode(
        &mut self,
        ids: &[i32],
        positions: &[i32],
        seq_lens: &[i32],
        block_tables: &[i32],
    ) -> Result<StepOutput, RuntimeError>;

    /// Whether [`Self::copy_page`] works on this backend. When it does,
    /// KV forking copies a partially-filled tail page device-side and
    /// copy-on-write un-shares pages without recompute; when it does
    /// not, the engine falls back to recomputing the affected positions
    /// (exact, just slower).
    fn supports_page_copy(&self) -> bool {
        false
    }

    /// Copy the full KV contents of page `src` into page `dst`
    /// device-side. Both must be valid non-garbage pages of the pool.
    /// Used by the fork/copy-on-write machinery; never on the logits
    /// path, so the default for backends without a copy primitive is a
    /// structured error (the engine checks [`Self::supports_page_copy`]
    /// first and routes around it).
    fn copy_page(&mut self, src: u32, dst: u32) -> Result<(), RuntimeError> {
        let _ = (src, dst);
        Err(RuntimeError::Shape("backend has no page-copy primitive".into()))
    }

    /// Bytes of weight traffic one step touches (browser cost model).
    fn weight_bytes(&self) -> usize;

    /// Wall time spent loading/compiling this model.
    fn load_seconds(&self) -> f64;
}
