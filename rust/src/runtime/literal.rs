//! Host <-> device marshalling helpers.

use xla::{ElementType, PjRtBuffer, PjRtClient};

/// Upload an i32 tensor.
pub fn i32_buffer(
    client: &PjRtClient,
    data: &[i32],
    dims: &[usize],
) -> Result<PjRtBuffer, xla::Error> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    client.buffer_from_host_buffer(data, dims, None)
}

/// Upload raw little-endian bytes with an explicit element type (weights:
/// u32 packed nibbles / f32 scales).
///
/// NOTE: this deliberately avoids `buffer_from_host_raw_bytes`, which in
/// xla 0.1.6 passes the `ElementType` discriminant where PJRT expects a
/// `PrimitiveType` — F32 uploads arrive half-sized. The typed
/// `buffer_from_host_buffer` path converts correctly; the one-time copy
/// into an aligned typed Vec happens only at model load.
pub fn raw_buffer(
    client: &PjRtClient,
    ty: ElementType,
    bytes: &[u8],
    dims: &[usize],
) -> Result<PjRtBuffer, xla::Error> {
    match ty {
        ElementType::F32 => {
            let v: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            client.buffer_from_host_buffer(&v, dims, None)
        }
        ElementType::U32 => {
            let v: Vec<u32> = bytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            client.buffer_from_host_buffer(&v, dims, None)
        }
        ElementType::S32 => {
            let v: Vec<i32> = bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            client.buffer_from_host_buffer(&v, dims, None)
        }
        other => Err(xla::Error::UnsupportedElementType {
            ty: other.primitive_type(),
            op: "raw_buffer",
        }),
    }
}

/// Upload an all-zero f32 tensor (fresh KV pool).
pub fn zero_f32_buffer(
    client: &PjRtClient,
    dims: &[usize],
) -> Result<PjRtBuffer, xla::Error> {
    let n: usize = dims.iter().product();
    let zeros = vec![0f32; n];
    client.buffer_from_host_buffer(&zeros, dims, None)
}

pub fn dtype_of(name: &str) -> Result<ElementType, String> {
    match name {
        "f32" => Ok(ElementType::F32),
        "u32" => Ok(ElementType::U32),
        "i32" => Ok(ElementType::S32),
        other => Err(format!("unsupported dtype '{other}'")),
    }
}
