//! Model execution: compiled executables + device-resident state.

use super::backend::ModelBackend;
use super::literal::{dtype_of, i32_buffer, raw_buffer, zero_f32_buffer};
use crate::browser::BrowserEnv;
use crate::models::{Manifest, ModelRecord, WeightFile};
use std::collections::BTreeMap;
use std::fmt;
use std::rc::Rc;
use std::time::Instant;
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

#[derive(Debug)]
pub enum RuntimeError {
    Xla(xla::Error),
    Artifact(String),
    Shape(String),
    /// A spurious backend hiccup (dropped queue submission, transient
    /// kernel failure): the op is safe to retry as-is — no device state
    /// was corrupted. The engine retries with bounded backoff and
    /// escalates to a device reset if the fault persists.
    Transient(String),
    /// The device — and with it every backend-resident KV page — is gone:
    /// the offline analog of WebGPU's `device.lost`. Sticky until the
    /// backend's `reset_cache` restores a fresh (empty) pool; host-side
    /// KV metadata must be invalidated and recomputed.
    DeviceLost(String),
}

/// Recovery class of a [`RuntimeError`], the engine's dispatch key:
/// retry, reset-and-recompute, or give up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultClass {
    /// Retryable in place ([`RuntimeError::Transient`]).
    Transient,
    /// Device and KV pool gone ([`RuntimeError::DeviceLost`]); recover
    /// via `reset_cache` + preempt-all + recompute.
    DeviceLost,
    /// Engine/artifact/shape bugs — not recoverable by the scheduler.
    Internal,
}

impl RuntimeError {
    pub fn class(&self) -> FaultClass {
        match self {
            RuntimeError::Transient(_) => FaultClass::Transient,
            RuntimeError::DeviceLost(_) => FaultClass::DeviceLost,
            _ => FaultClass::Internal,
        }
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::Xla(e) => write!(f, "xla error: {e}"),
            RuntimeError::Artifact(m) => write!(f, "artifact error: {m}"),
            RuntimeError::Shape(m) => write!(f, "shape error: {m}"),
            RuntimeError::Transient(m) => write!(f, "transient backend fault: {m}"),
            RuntimeError::DeviceLost(m) => write!(f, "device lost: {m}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e)
    }
}

/// Result of one prefill/decode step.
pub struct StepOutput {
    /// Row-major logits: prefill -> [vocab]; decode -> [batch, vocab].
    pub logits: Vec<f32>,
    /// Kernel dispatches this step (for the browser cost model; estimated
    /// from the layer structure like WebGPU submit counts would be).
    pub dispatches: usize,
    /// Pure executable wall time (excludes overhead injection).
    pub exec_seconds: f64,
}

/// One loaded model: compiled executables, device-resident weights, and
/// the chained KV-pool buffers. Not Send — lives on the worker thread.
pub struct ModelRuntime {
    client: PjRtClient,
    pub record: Rc<ModelRecord>,
    prefill: BTreeMap<usize, PjRtLoadedExecutable>,
    decode: BTreeMap<usize, PjRtLoadedExecutable>,
    weights: Vec<PjRtBuffer>,
    k_pages: PjRtBuffer,
    v_pages: PjRtBuffer,
    /// Per-step kernel dispatch estimate (see `dispatch_estimate`).
    dispatches_per_step: usize,
    /// Browser-environment cost model; `None` in native mode.
    env: Option<BrowserEnv>,
    /// Compile + upload time, reported once (model load UX in the paper).
    pub load_seconds: f64,
}

impl ModelRuntime {
    /// Load a model from the manifest: compile every phase executable and
    /// upload weights. `batches`/`chunks` can restrict compilation to the
    /// shapes a bench actually uses (compile time is per static shape).
    pub fn load(
        client: &PjRtClient,
        manifest: &Manifest,
        model: &str,
        env: Option<BrowserEnv>,
    ) -> Result<Self, RuntimeError> {
        Self::load_subset(client, manifest, model, env, None, None)
    }

    pub fn load_subset(
        client: &PjRtClient,
        manifest: &Manifest,
        model: &str,
        env: Option<BrowserEnv>,
        chunks: Option<&[usize]>,
        batches: Option<&[usize]>,
    ) -> Result<Self, RuntimeError> {
        let t0 = Instant::now();
        let record = manifest.model(model).map_err(RuntimeError::Artifact)?;

        let mut prefill = BTreeMap::new();
        for (&chunk, entry) in &record.prefill {
            if chunks.map_or(false, |cs| !cs.contains(&chunk)) {
                continue;
            }
            prefill.insert(chunk, compile_hlo(client, &entry.path)?);
        }
        let mut decode = BTreeMap::new();
        for (&batch, entry) in &record.decode {
            if batches.map_or(false, |bs| !bs.contains(&batch)) {
                continue;
            }
            decode.insert(batch, compile_hlo(client, &entry.path)?);
        }
        if prefill.is_empty() || decode.is_empty() {
            return Err(RuntimeError::Artifact("no executables selected".into()));
        }

        // Upload weights (once; device-resident for the model's lifetime).
        let file = WeightFile::load(record).map_err(RuntimeError::Artifact)?;
        let mut weights = Vec::with_capacity(record.weights.len());
        for e in &record.weights {
            let ty = dtype_of(&e.spec.dtype).map_err(RuntimeError::Artifact)?;
            weights.push(raw_buffer(client, ty, file.bytes(e), &e.spec.shape)?);
        }

        // Fresh zeroed KV pools.
        let kc = &record.cache[0];
        let vc = &record.cache[1];
        let k_pages = zero_f32_buffer(client, &kc.shape)?;
        let v_pages = zero_f32_buffer(client, &vc.shape)?;

        let dispatches_per_step = dispatch_estimate(&record.config);
        Ok(Self {
            client: client.clone(),
            record: Rc::new(record.clone()),
            prefill,
            decode,
            weights,
            k_pages,
            v_pages,
            dispatches_per_step,
            env,
            load_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    pub fn config(&self) -> &crate::models::ModelConfig {
        &self.record.config
    }

    pub fn compiled_chunks(&self) -> Vec<usize> {
        self.prefill.keys().copied().collect()
    }

    pub fn compiled_batches(&self) -> Vec<usize> {
        self.decode.keys().copied().collect()
    }

    /// Reset the KV pools to zero (bench isolation).
    pub fn reset_cache(&mut self) -> Result<(), RuntimeError> {
        let kc = &self.record.cache[0];
        let vc = &self.record.cache[1];
        self.k_pages = zero_f32_buffer(&self.client, &kc.shape)?;
        self.v_pages = zero_f32_buffer(&self.client, &vc.shape)?;
        Ok(())
    }

    /// Run one positioned prefill chunk for a single sequence (the
    /// [`ModelBackend::prefill_chunk`] contract).
    ///
    /// `ids` must already be padded to a compiled chunk size; the `n`
    /// valid tokens occupy absolute positions `start_pos..start_pos + n`
    /// addressed through `block_table` (padded with 0 to
    /// max_pages_per_seq). The compiled executable (see
    /// `python/compile/aot.py::lower_prefill`) takes
    /// `[ids, start_pos, n, block_table]`, writes the chunk's KV into
    /// the pool pages, and attends over the full pool-resident prefix
    /// `[0, start_pos + n)`. Returns last-valid-token logits `[vocab]`.
    pub fn prefill_chunk(
        &mut self,
        ids: &[i32],
        start_pos: usize,
        n: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        let chunk = ids.len();
        let exe = self.prefill.get(&chunk).ok_or_else(|| {
            RuntimeError::Shape(format!(
                "no prefill executable for chunk {chunk} (have {:?})",
                self.compiled_chunks()
            ))
        })?;
        let mp = self.record.config.max_pages_per_seq();
        if block_table.len() != mp {
            return Err(RuntimeError::Shape(format!(
                "block_table len {} != {mp}",
                block_table.len()
            )));
        }
        if n == 0 || n > chunk {
            return Err(RuntimeError::Shape(format!("chunk n {n} not in 1..={chunk}")));
        }
        if start_pos + n > mp * self.record.config.page_size {
            return Err(RuntimeError::Shape(format!(
                "chunk end {} beyond the block table's reach",
                start_pos + n
            )));
        }

        let ids_b = i32_buffer(&self.client, ids, &[chunk])?;
        let start_b = i32_buffer(&self.client, &[start_pos as i32], &[1])?;
        let len_b = i32_buffer(&self.client, &[n as i32], &[1])?;
        let bt_b = i32_buffer(&self.client, block_table, &[mp])?;

        let t0 = Instant::now();
        let inputs: Vec<&PjRtBuffer> = [&ids_b, &start_b, &len_b, &bt_b]
            .into_iter()
            .chain(self.weights.iter())
            .chain([&self.k_pages, &self.v_pages])
            .collect();
        let mut out = exe.execute_b(&inputs)?;
        let logits = self.take_outputs(&mut out)?;
        let exec_seconds = t0.elapsed().as_secs_f64();

        // Browser mode: the prefill chunk is one round of kernel
        // dispatches just like a decode step.
        if let Some(env) = &self.env {
            env.charge_dispatches(self.dispatches_per_step, self.weight_bytes());
        }
        Ok(StepOutput { logits, dispatches: self.dispatches_per_step, exec_seconds })
    }

    /// Whole-prompt prefill from position 0 (benches / direct tests).
    pub fn prefill(
        &mut self,
        ids: &[i32],
        seq_len: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        self.prefill_chunk(ids, 0, seq_len, block_table)
    }

    /// Run one batched decode step.
    ///
    /// All slices are `batch`-sized (a compiled batch size); padding slots
    /// use seq_len 0 / position 0 / block-table row of zeros. Returns
    /// logits `[batch * vocab]`.
    pub fn decode(
        &mut self,
        ids: &[i32],
        positions: &[i32],
        seq_lens: &[i32],
        block_tables: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        let batch = ids.len();
        let exe = self.decode.get(&batch).ok_or_else(|| {
            RuntimeError::Shape(format!(
                "no decode executable for batch {batch} (have {:?})",
                self.compiled_batches()
            ))
        })?;
        let mp = self.record.config.max_pages_per_seq();
        if positions.len() != batch || seq_lens.len() != batch {
            return Err(RuntimeError::Shape("positions/seq_lens length mismatch".into()));
        }
        if block_tables.len() != batch * mp {
            return Err(RuntimeError::Shape(format!(
                "block_tables len {} != {}",
                block_tables.len(),
                batch * mp
            )));
        }

        let ids_b = i32_buffer(&self.client, ids, &[batch])?;
        let pos_b = i32_buffer(&self.client, positions, &[batch])?;
        let len_b = i32_buffer(&self.client, seq_lens, &[batch])?;
        let bt_b = i32_buffer(&self.client, block_tables, &[batch, mp])?;

        let t0 = Instant::now();
        let inputs: Vec<&PjRtBuffer> = [&ids_b, &pos_b, &len_b, &bt_b]
            .into_iter()
            .chain(self.weights.iter())
            .chain([&self.k_pages, &self.v_pages])
            .collect();
        let mut out = exe.execute_b(&inputs)?;
        let logits = self.take_outputs(&mut out)?;
        let exec_seconds = t0.elapsed().as_secs_f64();

        if let Some(env) = &self.env {
            env.charge_dispatches(self.dispatches_per_step, self.weight_bytes());
        }
        Ok(StepOutput { logits, dispatches: self.dispatches_per_step, exec_seconds })
    }

    /// Pull (logits, k_pages, v_pages) out of an execute result; the cache
    /// buffers replace the chained state with zero host traffic.
    fn take_outputs(&mut self, out: &mut Vec<Vec<PjRtBuffer>>) -> Result<Vec<f32>, RuntimeError> {
        let outputs = out
            .pop()
            .ok_or_else(|| RuntimeError::Shape("no output replica".into()))?;
        if outputs.len() != 3 {
            return Err(RuntimeError::Shape(format!(
                "expected 3 outputs (logits, k, v), got {}",
                outputs.len()
            )));
        }
        let mut it = outputs.into_iter();
        let logits_buf = it.next().unwrap();
        self.k_pages = it.next().unwrap();
        self.v_pages = it.next().unwrap();
        let logits = logits_buf.to_literal_sync()?.to_vec::<f32>()?;
        Ok(logits)
    }

    fn weight_bytes(&self) -> usize {
        self.record.weights.iter().map(|w| w.nbytes).sum()
    }
}

/// The XLA runtime is one [`ModelBackend`]; the engine only ever sees
/// the trait. Inherent methods stay for the benches and runtime tests
/// that drive this backend directly.
impl ModelBackend for ModelRuntime {
    fn config(&self) -> &crate::models::ModelConfig {
        ModelRuntime::config(self)
    }

    fn compiled_chunks(&self) -> Vec<usize> {
        ModelRuntime::compiled_chunks(self)
    }

    fn compiled_batches(&self) -> Vec<usize> {
        ModelRuntime::compiled_batches(self)
    }

    fn reset_cache(&mut self) -> Result<(), RuntimeError> {
        ModelRuntime::reset_cache(self)
    }

    fn prefill_chunk(
        &mut self,
        ids: &[i32],
        start_pos: usize,
        n: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        ModelRuntime::prefill_chunk(self, ids, start_pos, n, block_table)
    }

    fn decode(
        &mut self,
        ids: &[i32],
        positions: &[i32],
        seq_lens: &[i32],
        block_tables: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        ModelRuntime::decode(self, ids, positions, seq_lens, block_tables)
    }

    fn weight_bytes(&self) -> usize {
        ModelRuntime::weight_bytes(self)
    }

    fn load_seconds(&self) -> f64 {
        self.load_seconds
    }
}

fn compile_hlo(
    client: &PjRtClient,
    path: &std::path::Path,
) -> Result<PjRtLoadedExecutable, RuntimeError> {
    let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
        RuntimeError::Artifact(format!("parse {}: {e}", path.display()))
    })?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Kernel-dispatch estimate per engine step — the count of WebGPU
/// `dispatchWorkgroups` submissions WebLLM's compiled model issues per
/// token: per layer 2 norms + 4 projection GEMMs + rope + attention +
/// 3 MLP GEMMs + cache append, plus embedding + final norm + lm_head.
/// Shared with the reference backend so both charge the browser cost
/// model identically.
pub(crate) fn dispatch_estimate(cfg: &crate::models::ModelConfig) -> usize {
    cfg.n_layers * 11 + 3
}
