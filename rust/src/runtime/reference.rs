//! Deterministic reference backend: the [`ModelBackend`] contract in
//! pure Rust, no artifacts, no PJRT.
//!
//! The role model is llguidance's practice of pinning constrained
//! decoding against an exhaustive reference implementation: instead of a
//! neural network, logits here are a *seeded-deterministic function of
//! the full token prefix* a sequence's block table addresses. That makes
//! every KV-cache behavior checkable with exact equality:
//!
//! * **cache chaining** — decoding the same token at a longer prefix
//!   must change the logits (the prefix fingerprint grew);
//! * **batch transparency** — a row's logits depend only on its own
//!   prefix, so the b=1 and padded b=4 executables must agree bit-for-bit;
//! * **prefix-page reuse** — a reused page already holds the right
//!   tokens, so a prefix-cache hit is indistinguishable from a rewrite;
//! * **padding slots** — seq_len-0 rows are skipped entirely and can
//!   never leak into live rows.
//!
//! The backend keeps a token-per-slot page pool mirroring the real
//! device cache's geometry (`num_pages` x `page_size`). A positioned
//! prefill chunk writes positions `start_pos..start_pos + n` through the
//! block table; decode writes the stepped token at its position. Both
//! then "attend" by folding every cached position of the full prefix
//! into a fingerprint that seeds the logit hash — so chunked prefill is
//! *exactly* whole-prompt prefill (the fingerprint only sees the final
//! page contents), which is what makes the scheduler's chunking and
//! prefix-skip checkable by exact equality. Reading a never-written slot
//! is a hard error — a scheduler or block-table bug surfaces as a failed
//! test, not silent garbage.

use super::backend::ModelBackend;
use super::exec::{dispatch_estimate, RuntimeError, StepOutput};
use crate::browser::BrowserEnv;
use crate::models::ModelConfig;
use std::time::Instant;

/// Slot sentinel: no token has ever been written here.
const UNWRITTEN: i32 = -1;

/// Synthetic compute burned per (token, layer) each step, in hash
/// rounds (~0.1–0.3 us per token-layer on commodity CPUs). The backend
/// models *cost*, not just content: a 64-token prefill chunk takes
/// measurably (and roughly proportionally) longer than a 16-token one,
/// so scheduler latency effects — decode stall behind a big chunk, the
/// TTFT/ITL trade of `EngineConfig::prefill_token_budget` — are
/// observable offline with the same ordering a kernel backend shows.
/// Small enough that test suites spend only low single-digit
/// milliseconds here in total.
const WORK_ROUNDS_PER_TOKEN_LAYER: usize = 150;

/// SplitMix64: the one-shot mixer behind both the prefix fingerprint and
/// the per-token logit hash.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Pure-Rust seeded-deterministic [`ModelBackend`]. See module docs.
pub struct ReferenceBackend {
    config: ModelConfig,
    /// Model identity: mixed from the engine seed and the model name, so
    /// two models loaded in one engine disagree on every logit while two
    /// engines loading the same model agree exactly.
    seed: u64,
    /// Token id whose logit gets a deterministic boost on ~1/13 of
    /// states, so unconstrained generations stop organically (finish
    /// reason diversity) instead of always running to `max_tokens`.
    stop_token: Option<u32>,
    /// Flat `[num_pages * page_size]` token-per-slot pool.
    pages: Vec<i32>,
    /// Browser-environment cost model; `None` in native mode.
    env: Option<BrowserEnv>,
    dispatches_per_step: usize,
    load_seconds: f64,
}

impl ReferenceBackend {
    /// Build a backend for `config`. `seed` is the engine-level model
    /// seed (the model name is mixed in internally); `stop_token` is the
    /// tokenizer's EOS id, if generation should be able to end early.
    pub fn new(
        config: ModelConfig,
        seed: u64,
        stop_token: Option<u32>,
        env: Option<BrowserEnv>,
    ) -> Self {
        let t0 = Instant::now();
        let pages = vec![UNWRITTEN; config.num_pages * config.page_size];
        let dispatches_per_step = dispatch_estimate(&config);
        let seed = splitmix64(seed ^ fnv1a(config.name.as_bytes()));
        Self {
            config,
            seed,
            stop_token,
            pages,
            env,
            dispatches_per_step,
            load_seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// The mixed per-model seed (test introspection).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Flat pool index for `pos` under `table`, validating the page id.
    fn page_slot(&self, pos: usize, table: &[i32]) -> Result<usize, RuntimeError> {
        let ps = self.config.page_size;
        let page = *table.get(pos / ps).ok_or_else(|| {
            RuntimeError::Shape(format!("position {pos} beyond block table"))
        })?;
        if page < 0 || page as usize >= self.config.num_pages {
            return Err(RuntimeError::Shape(format!(
                "page {page} out of pool (num_pages {})",
                self.config.num_pages
            )));
        }
        Ok(page as usize * ps + pos % ps)
    }

    /// Fold every cached token of the prefix `[0, seq_len)` into a
    /// fingerprint — the reference analog of attention over the KV
    /// cache. Order- and content-sensitive; errors on unwritten slots.
    fn prefix_fingerprint(&self, seq_len: usize, table: &[i32]) -> Result<u64, RuntimeError> {
        let mut h = self.seed ^ 0xA076_1D64_78BD_642F;
        for pos in 0..seq_len {
            let tok = self.pages[self.page_slot(pos, table)?];
            if tok == UNWRITTEN {
                return Err(RuntimeError::Shape(format!(
                    "KV position {pos} read before any write (page {}, slot {})",
                    table[pos / self.config.page_size],
                    pos % self.config.page_size
                )));
            }
            h = splitmix64(h ^ (tok as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        Ok(h)
    }

    /// Fill `out` (one `[vocab]` row) from the prefix fingerprint: every
    /// logit uniform in [-4, 4), plus the deterministic EOS boost.
    fn fill_logits(&self, h: u64, out: &mut [f32]) {
        for (v, slot) in out.iter_mut().enumerate() {
            let r = splitmix64(h ^ (v as u64).wrapping_mul(0xD1B5_4A32_D192_ED03));
            *slot = (r >> 40) as f32 / (1u64 << 24) as f32 * 8.0 - 4.0;
        }
        if let Some(eos) = self.stop_token {
            if let Some(slot) = out.get_mut(eos as usize) {
                if splitmix64(h ^ 0xE05) % 13 == 0 {
                    // +8 dominates the [-4, 4) band: greedy decode stops
                    // here, and softmax sampling almost surely does.
                    *slot += 8.0;
                }
            }
        }
    }

    fn charge_env(&self) {
        if let Some(env) = &self.env {
            env.charge_dispatches(self.dispatches_per_step, ModelBackend::weight_bytes(self));
        }
    }

    /// Burn the synthetic per-token compute for a step that processed
    /// `tokens` tokens (see [`WORK_ROUNDS_PER_TOKEN_LAYER`]). Runs inside
    /// the timed section so `exec_seconds` reflects it.
    fn burn_compute(&self, tokens: usize) {
        let rounds = tokens * self.config.n_layers * WORK_ROUNDS_PER_TOKEN_LAYER;
        let mut acc = self.seed;
        for i in 0..rounds as u64 {
            acc = splitmix64(acc ^ i);
        }
        std::hint::black_box(acc);
    }
}

impl ModelBackend for ReferenceBackend {
    fn config(&self) -> &ModelConfig {
        &self.config
    }

    fn compiled_chunks(&self) -> Vec<usize> {
        self.config.prefill_chunks.clone()
    }

    fn compiled_batches(&self) -> Vec<usize> {
        self.config.decode_batches.clone()
    }

    fn reset_cache(&mut self) -> Result<(), RuntimeError> {
        self.pages.fill(UNWRITTEN);
        Ok(())
    }

    fn supports_page_copy(&self) -> bool {
        true
    }

    fn copy_page(&mut self, src: u32, dst: u32) -> Result<(), RuntimeError> {
        let np = self.config.num_pages;
        for page in [src, dst] {
            // Page 0 is the garbage page: copying from it would launder
            // unwritten slots into a live table, copying into it would
            // corrupt every padding row.
            if page == 0 || page as usize >= np {
                return Err(RuntimeError::Shape(format!(
                    "copy_page {page} out of pool (num_pages {np})"
                )));
            }
        }
        let ps = self.config.page_size;
        let s = src as usize * ps;
        self.pages.copy_within(s..s + ps, dst as usize * ps);
        Ok(())
    }

    fn prefill_chunk(
        &mut self,
        ids: &[i32],
        start_pos: usize,
        n: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        let chunk = ids.len();
        if !self.config.prefill_chunks.contains(&chunk) {
            return Err(RuntimeError::Shape(format!(
                "no prefill executable for chunk {chunk} (have {:?})",
                self.compiled_chunks()
            )));
        }
        let mp = self.config.max_pages_per_seq();
        if block_table.len() != mp {
            return Err(RuntimeError::Shape(format!(
                "block_table len {} != {mp}",
                block_table.len()
            )));
        }
        if n == 0 || n > chunk {
            return Err(RuntimeError::Shape(format!("chunk n {n} not in 1..={chunk}")));
        }
        if start_pos + n > mp * self.config.page_size {
            return Err(RuntimeError::Shape(format!(
                "chunk end {} beyond the block table's reach",
                start_pos + n
            )));
        }

        let t0 = Instant::now();
        // Write the chunk's tokens at their absolute positions; the
        // fingerprint then reads the *whole* prefix [0, start_pos + n)
        // back through the table, so a skipped-but-unwritten leading
        // position (scheduler bug, bogus prefix skip) is a hard
        // "read before any write" error, not silent garbage.
        for (i, &tok) in ids.iter().enumerate().take(n) {
            let slot = self.page_slot(start_pos + i, block_table)?;
            self.pages[slot] = tok;
        }
        let h = self.prefix_fingerprint(start_pos + n, block_table)?;
        let mut logits = vec![0.0f32; self.config.vocab_size];
        self.fill_logits(h, &mut logits);
        self.burn_compute(n);
        let exec_seconds = t0.elapsed().as_secs_f64();

        self.charge_env();
        Ok(StepOutput { logits, dispatches: self.dispatches_per_step, exec_seconds })
    }

    fn verify_chunk(
        &mut self,
        ids: &[i32],
        start_pos: usize,
        n: usize,
        block_table: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        let chunk = ids.len();
        if !self.config.prefill_chunks.contains(&chunk) {
            return Err(RuntimeError::Shape(format!(
                "no verify executable for chunk {chunk} (have {:?})",
                self.compiled_chunks()
            )));
        }
        let mp = self.config.max_pages_per_seq();
        if block_table.len() != mp {
            return Err(RuntimeError::Shape(format!(
                "block_table len {} != {mp}",
                block_table.len()
            )));
        }
        if n == 0 || n > chunk {
            return Err(RuntimeError::Shape(format!("chunk n {n} not in 1..={chunk}")));
        }
        if start_pos + n > mp * self.config.page_size {
            return Err(RuntimeError::Shape(format!(
                "chunk end {} beyond the block table's reach",
                start_pos + n
            )));
        }

        let t0 = Instant::now();
        // One pass: fold the resident prefix [0, start_pos) once, then
        // extend the fingerprint incrementally per verified token — the
        // whole run is scored with O(prefix + n) work instead of the
        // default implementation's n separate decode passes. Because the
        // fingerprint after position i only sees positions [0, i], each
        // row is bit-identical to a sequential decode of the same
        // prefix, which is what makes accept/reject exactly testable.
        let vocab = self.config.vocab_size;
        let mut h = self.seed ^ 0xA076_1D64_78BD_642F;
        for pos in 0..start_pos {
            let tok = self.pages[self.page_slot(pos, block_table)?];
            if tok == UNWRITTEN {
                return Err(RuntimeError::Shape(format!(
                    "KV position {pos} read before any write (page {}, slot {})",
                    block_table[pos / self.config.page_size],
                    pos % self.config.page_size
                )));
            }
            h = splitmix64(h ^ (tok as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        let mut logits = vec![0.0f32; n * vocab];
        for (i, &tok) in ids.iter().enumerate().take(n) {
            let slot = self.page_slot(start_pos + i, block_table)?;
            self.pages[slot] = tok;
            h = splitmix64(h ^ (tok as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            self.fill_logits(h, &mut logits[i * vocab..(i + 1) * vocab]);
        }
        self.burn_compute(n);
        let exec_seconds = t0.elapsed().as_secs_f64();

        self.charge_env();
        Ok(StepOutput { logits, dispatches: self.dispatches_per_step, exec_seconds })
    }

    fn decode(
        &mut self,
        ids: &[i32],
        positions: &[i32],
        seq_lens: &[i32],
        block_tables: &[i32],
    ) -> Result<StepOutput, RuntimeError> {
        let batch = ids.len();
        if !self.config.decode_batches.contains(&batch) {
            return Err(RuntimeError::Shape(format!(
                "no decode executable for batch {batch} (have {:?})",
                self.compiled_batches()
            )));
        }
        let mp = self.config.max_pages_per_seq();
        if positions.len() != batch || seq_lens.len() != batch {
            return Err(RuntimeError::Shape("positions/seq_lens length mismatch".into()));
        }
        if block_tables.len() != batch * mp {
            return Err(RuntimeError::Shape(format!(
                "block_tables len {} != {}",
                block_tables.len(),
                batch * mp
            )));
        }

        let t0 = Instant::now();
        let vocab = self.config.vocab_size;
        let mut logits = vec![0.0f32; batch * vocab];
        for row in 0..batch {
            let len = seq_lens[row];
            if len <= 0 {
                continue; // padding slot: untouched, logits stay zero
            }
            let len = len as usize;
            let pos = positions[row];
            if pos < 0 || pos as usize != len - 1 {
                return Err(RuntimeError::Shape(format!(
                    "row {row}: position {pos} is not seq_len-1 ({len})"
                )));
            }
            let table = &block_tables[row * mp..(row + 1) * mp];
            let slot = self.page_slot(pos as usize, table)?;
            self.pages[slot] = ids[row];
            let h = self.prefix_fingerprint(len, table)?;
            self.fill_logits(h, &mut logits[row * vocab..(row + 1) * vocab]);
            self.burn_compute(1);
        }
        let exec_seconds = t0.elapsed().as_secs_f64();

        self.charge_env();
        Ok(StepOutput { logits, dispatches: self.dispatches_per_step, exec_seconds })
    }

    fn weight_bytes(&self) -> usize {
        // Synthetic f32 footprint; feeds the browser bandwidth tax.
        self.config.param_count as usize * 4
    }

    fn load_seconds(&self) -> f64 {
        self.load_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::reference_model_config;

    fn backend() -> ReferenceBackend {
        ReferenceBackend::new(reference_model_config("tiny-ref").unwrap(), 7, Some(2), None)
    }

    fn padded(ids: &[i32], chunk: usize) -> Vec<i32> {
        let mut v = vec![0i32; chunk];
        v[..ids.len()].copy_from_slice(ids);
        v
    }

    #[test]
    fn same_prefix_same_logits_across_instances() {
        let mut a = backend();
        let mut b = backend();
        let mp = a.config().max_pages_per_seq();
        let mut bt = vec![0i32; mp];
        bt[0] = 1;
        let ids = padded(&[5, 6, 7], 16);
        assert_eq!(
            a.prefill(&ids, 3, &bt).unwrap().logits,
            b.prefill(&ids, 3, &bt).unwrap().logits
        );
    }

    #[test]
    fn logits_are_order_sensitive() {
        let mut a = backend();
        let mut b = backend();
        let mp = a.config().max_pages_per_seq();
        let mut bt = vec![0i32; mp];
        bt[0] = 1;
        let x = a.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap().logits;
        let y = b.prefill(&padded(&[6, 5], 16), 2, &bt).unwrap().logits;
        assert_ne!(x, y, "swapping token order must change logits");
    }

    #[test]
    fn decode_sees_grown_context() {
        let mut rt = backend();
        let mp = rt.config().max_pages_per_seq();
        let mut bt = vec![0i32; mp];
        bt[0] = 1;
        bt[1] = 2;
        rt.prefill(&padded(&[10, 11, 12, 13], 16), 4, &bt).unwrap();
        let one = rt.decode(&[42], &[4], &[5], &bt).unwrap();
        let two = rt.decode(&[42], &[5], &[6], &bt).unwrap();
        assert_ne!(one.logits, two.logits, "cache state must affect logits");
    }

    #[test]
    fn reading_unwritten_kv_is_an_error() {
        let mut rt = backend();
        let mp = rt.config().max_pages_per_seq();
        let mut bt = vec![0i32; mp];
        bt[0] = 3;
        // Decode claims a 4-token prefix that was never prefilled.
        let err = rt.decode(&[9], &[3], &[4], &bt).unwrap_err();
        assert!(err.to_string().contains("read before any write"), "{err}");
    }

    #[test]
    fn chunked_prefill_equals_whole_prompt_exactly() {
        let prompt: Vec<i32> = (40..52).collect(); // 12 tokens, 2 pages
        let mut bt0 = vec![0i32; backend().config().max_pages_per_seq()];
        bt0[0] = 1;
        bt0[1] = 2;

        let mut whole = backend();
        let want = whole.prefill(&padded(&prompt, 16), 12, &bt0).unwrap().logits;

        // Same prompt fed as 5 + 7 positioned chunks.
        let mut chunked = backend();
        chunked.prefill_chunk(&padded(&prompt[..5], 16), 0, 5, &bt0).unwrap();
        let got = chunked.prefill_chunk(&padded(&prompt[5..], 16), 5, 7, &bt0).unwrap().logits;
        assert_eq!(want, got, "chunked prefill must be bit-identical to whole-prompt");
    }

    #[test]
    fn chunk_over_unwritten_prefix_is_an_error() {
        let mut rt = backend();
        let mut bt = vec![0i32; rt.config().max_pages_per_seq()];
        bt[0] = 1;
        bt[1] = 2;
        // Claim positions 0..6 are resident without ever writing them.
        let err = rt.prefill_chunk(&padded(&[9, 9], 16), 6, 2, &bt).unwrap_err();
        assert!(err.to_string().contains("read before any write"), "{err}");
    }

    #[test]
    fn chunk_beyond_table_reach_is_an_error() {
        let mut rt = backend();
        let mp = rt.config().max_pages_per_seq();
        let bt = vec![1i32; mp];
        let end = mp * rt.config().page_size;
        let err = rt.prefill_chunk(&padded(&[1], 16), end, 1, &bt).unwrap_err();
        assert!(err.to_string().contains("beyond"), "{err}");
    }

    #[test]
    fn verify_chunk_rows_equal_sequential_decode() {
        let prompt = [10i32, 11, 12];
        let run = [20i32, 21, 22, 23];
        let mut bt = vec![0i32; backend().config().max_pages_per_seq()];
        bt[0] = 1;
        bt[1] = 2;

        // Sequential truth: decode each run token one position at a time.
        let mut seq = backend();
        seq.prefill(&padded(&prompt, 16), 3, &bt).unwrap();
        let mut want = Vec::new();
        for (i, &tok) in run.iter().enumerate() {
            let pos = 3 + i;
            let out = seq.decode(&[tok], &[pos as i32], &[(pos + 1) as i32], &bt).unwrap();
            want.extend_from_slice(&out.logits);
        }

        // verify_chunk scores the same run in one positioned call.
        let mut ver = backend();
        ver.prefill(&padded(&prompt, 16), 3, &bt).unwrap();
        let got = ver.verify_chunk(&padded(&run, 16), 3, 4, &bt).unwrap().logits;
        assert_eq!(want, got, "verify rows must be bit-identical to sequential decode");
    }

    #[test]
    fn verify_chunk_writes_kv_like_prefill() {
        let mut bt = vec![0i32; backend().config().max_pages_per_seq()];
        bt[0] = 1;

        let mut a = backend();
        a.prefill(&padded(&[5, 6], 16), 2, &bt).unwrap();
        a.verify_chunk(&padded(&[7, 8], 16), 2, 2, &bt).unwrap();
        let after_verify = a.decode(&[9], &[4], &[5], &bt).unwrap().logits;

        let mut b = backend();
        b.prefill(&padded(&[5, 6, 7, 8], 16), 4, &bt).unwrap();
        let after_prefill = b.decode(&[9], &[4], &[5], &bt).unwrap().logits;
        assert_eq!(after_verify, after_prefill, "verified tokens must be resident KV");
    }

    #[test]
    fn verify_chunk_over_unwritten_prefix_is_an_error() {
        let mut rt = backend();
        let mut bt = vec![0i32; rt.config().max_pages_per_seq()];
        bt[0] = 1;
        let err = rt.verify_chunk(&padded(&[9], 16), 3, 1, &bt).unwrap_err();
        assert!(err.to_string().contains("read before any write"), "{err}");
    }

    #[test]
    fn model_name_changes_logits() {
        let mut a =
            ReferenceBackend::new(reference_model_config("tiny-ref").unwrap(), 7, None, None);
        let mut b =
            ReferenceBackend::new(reference_model_config("tiny-ref-b").unwrap(), 7, None, None);
        let mp = a.config().max_pages_per_seq();
        let mut bt = vec![0i32; mp];
        bt[0] = 1;
        let ids = padded(&[5], 16);
        assert_ne!(
            a.prefill(&ids, 1, &bt).unwrap().logits,
            b.prefill(&ids, 1, &bt).unwrap().logits
        );
    }
}
