//! The web-worker analog: a dedicated thread owning the `MLCEngine`,
//! driven entirely by wire messages (paper Figure 1, right half).
//!
//! The event loop mirrors a worker's message pump: block on the inbox
//! when idle; when the engine has in-flight sequences, poll the inbox
//! without blocking and run one scheduler step per iteration so new
//! messages (new requests, aborts) interleave with generation — this is
//! what keeps the "UI thread" responsive in the paper's design.

use super::engine::{EngineConfig, EngineEvent, MLCEngine};
use super::messages::{FromWorker, ToWorker};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

/// Handle to a running worker thread. Dropping shuts the worker down.
pub struct WorkerHandle {
    pub(crate) to_worker: Sender<String>,
    pub(crate) from_worker: Receiver<String>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn the worker and wait for its Ready message (model loading and
    /// artifact compilation happen inside the worker, like WebLLM's
    /// `CreateServiceWorkerMLCEngine` await).
    pub fn spawn(cfg: EngineConfig) -> Result<(Self, Vec<String>), String> {
        let (tx_in, rx_in) = channel::<String>();
        let (tx_out, rx_out) = channel::<String>();
        // Read the configured bound before `cfg` moves into the thread.
        let ready_timeout = cfg.engine_timeout();
        let join = std::thread::Builder::new()
            .name("mlc-worker".into())
            .spawn(move || worker_main(cfg, rx_in, tx_out))
            .map_err(|e| e.to_string())?;
        let handle = Self { to_worker: tx_in, from_worker: rx_out, join: Some(join) };
        // First message must be Ready (or an Error if loading failed).
        let first = handle
            .from_worker
            .recv_timeout(ready_timeout)
            .map_err(|e| format!("worker did not become ready: {e}"))?;
        match FromWorker::from_wire(&first)? {
            FromWorker::Ready { models } => Ok((handle, models)),
            FromWorker::Error { error, .. } => Err(error.to_string()),
            other => Err(format!("unexpected first message {other:?}")),
        }
    }

    pub fn post(&self, msg: &ToWorker) -> Result<(), String> {
        self.to_worker.send(msg.to_wire()).map_err(|e| e.to_string())
    }

    pub fn recv(&self, timeout: Duration) -> Result<FromWorker, String> {
        let wire = self
            .from_worker
            .recv_timeout(timeout)
            .map_err(|e| format!("worker channel: {e}"))?;
        FromWorker::from_wire(&wire)
    }

    pub fn try_recv(&self) -> Option<Result<FromWorker, String>> {
        self.from_worker.try_recv().ok().map(|w| FromWorker::from_wire(&w))
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.post(&ToWorker::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn worker_main(cfg: EngineConfig, inbox: Receiver<String>, outbox: Sender<String>) {
    let send = |msg: FromWorker| {
        let _ = outbox.send(msg.to_wire());
    };

    let mut engine = match MLCEngine::new(&cfg) {
        Ok(e) => e,
        Err(e) => {
            send(FromWorker::Error { id: 0, error: e });
            return;
        }
    };
    send(FromWorker::Ready { models: engine.loaded_models() });

    // request-id (wire) <-> engine request id mapping.
    let mut wire_of: HashMap<u64, u64> = HashMap::new();
    // Drained is announced once per drain request, after the last
    // resident request's events are flushed.
    let mut drained_announced = false;

    'outer: loop {
        // Message intake: blocking when idle, draining when busy.
        loop {
            let msg = if engine.has_work() {
                match inbox.try_recv() {
                    Ok(m) => Some(m),
                    Err(_) => None,
                }
            } else {
                match inbox.recv_timeout(Duration::from_millis(200)) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break 'outer,
                }
            };
            let Some(wire) = msg else { break };
            match ToWorker::from_wire(&wire) {
                Ok(ToWorker::ChatCompletion { id, request }) => {
                    match engine.submit(request) {
                        Ok(rid) => {
                            wire_of.insert(rid, id);
                        }
                        Err(e) => send(FromWorker::Error { id, error: e }),
                    }
                }
                Ok(ToWorker::Abort { id }) => {
                    // Find the engine id for this wire id.
                    if let Some((&rid, _)) = wire_of.iter().find(|(_, &w)| w == id) {
                        engine.abort(rid);
                    }
                }
                Ok(ToWorker::Stats) => {
                    send(FromWorker::Stats { payload: engine.stats_json() });
                }
                Ok(ToWorker::Drain { timeout_ms }) => {
                    engine.drain(timeout_ms);
                    drained_announced = false;
                }
                Ok(ToWorker::Shutdown) => break 'outer,
                Err(e) => send(FromWorker::Error {
                    id: 0,
                    error: crate::api::ApiError::invalid(format!("bad message: {e}")),
                }),
            }
        }

        // One scheduler step, then flush events. `step()` absorbs
        // recoverable faults (transient retries, device loss) internally;
        // an `Err` here is a genuine internal failure.
        if engine.has_work() {
            if let Err(e) = engine.step() {
                // Engine-level failure: fail every in-flight request.
                for (&rid, &wid) in &wire_of {
                    let _ = rid;
                    send(FromWorker::Error { id: wid, error: e.clone() });
                }
                wire_of.clear();
                continue;
            }
        }
        for ev in engine.poll_events() {
            match ev {
                EngineEvent::Chunk(rid, chunk) => {
                    if let Some(&wid) = wire_of.get(&rid) {
                        send(FromWorker::Chunk { id: wid, chunk });
                    }
                }
                EngineEvent::Done(rid, response) => {
                    if let Some(wid) = wire_of.remove(&rid) {
                        send(FromWorker::Done { id: wid, response });
                    }
                }
                EngineEvent::Error(rid, error) => {
                    if let Some(wid) = wire_of.remove(&rid) {
                        send(FromWorker::Error { id: wid, error });
                    }
                }
            }
        }
        if engine.drained() && !drained_announced {
            drained_announced = true;
            send(FromWorker::Drained);
        }
    }
}
